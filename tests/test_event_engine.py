"""Event-kernel engine: cross-engine parity, on-device sampling, budgets.

The contract under test (PR 4 acceptance):

- event kernel == step kernel == scalar oracle, trajectory-for-trajectory
  AND trial-mean-for-trial-mean, bit-for-bit, under a shared host-supplied
  DYADIC gap schedule (every quantity exactly representable: the closed
  forms and the step accumulations then perform exact arithmetic), for
  every FailureProcess;
- the same at ~1e-12 relative tolerance for arbitrary float schedules;
- the on-device threefry sampler is deterministic in the seed and
  distribution-identical to the host sampler;
- per-point power-of-two budget bucketing dispatches each grid point at
  its own scan length without changing results.
"""
import math

import numpy as np
import pytest

from repro.core import (CheckpointParams, EXASCALE_POWER_RHO55,
                        Exponential, LogNormal, TraceReplay, Weibull,
                        fig12_checkpoint, simulate_once)
from repro.core import optimal
from repro.sim import ParamGrid, simulate_candidates, simulate_trajectories
from repro.sim.engine import (fail_capacity_points, presample_gaps,
                              presample_gaps_device, step_budget_points)

CK = fig12_checkpoint(300.0)
PW = EXASCALE_POWER_RHO55

PROCESSES = [
    Exponential(),
    Weibull(shape=0.6),
    LogNormal(sigma=1.0),
    TraceReplay(gaps=[40.0, 500.0, 120.0, 90.0, 800.0, 33.0]),
]

#: dyadic rounding grid: coarse enough that boundary coincidences with the
#: engines' 1e-12 completion slack are impossible, fine enough to keep the
#: schedule's distribution intact.
_DYADIC = 2.0 ** 16


def _dyadic(gaps):
    return np.maximum(np.round(gaps * _DYADIC) / _DYADIC, 1.0 / _DYADIC)


def _fields(tb):
    return {f: getattr(tb, f) for f in
            ("wall_time", "energy", "work_executed", "io_time", "down_time",
             "n_failures", "n_checkpoints", "truncated", "gaps_exhausted")}


class TestCrossEngineParity:
    """event == step == scalar under shared host schedules."""

    @pytest.mark.parametrize("proc", PROCESSES, ids=lambda p: p.name)
    def test_bitexact_on_dyadic_schedule(self, proc):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        gaps = _dyadic(presample_gaps(grid, 8, 128, seed=9, process=proc))
        ev = simulate_trajectories(60.0, grid, T_base=3000.0, gaps=gaps,
                                   engine_kind="event")
        st = simulate_trajectories(60.0, grid, T_base=3000.0, gaps=gaps,
                                   engine_kind="step")
        assert not ev.truncated.any() and not st.truncated.any()
        for name, a in _fields(ev).items():
            np.testing.assert_array_equal(a, getattr(st, name),
                                          err_msg=f"{proc.name}/{name}")
        # trial means bit-for-bit (the acceptance criterion's phrasing)
        assert np.array_equal(ev.wall_time.mean(axis=-1),
                              st.wall_time.mean(axis=-1))
        assert np.array_equal(ev.energy.mean(axis=-1),
                              st.energy.mean(axis=-1))
        # ...and the scalar oracle agrees exactly on the same schedules
        for k in range(gaps.shape[1]):
            ref = simulate_once(60.0, CK, PW, 3000.0,
                                np.random.default_rng(0), gaps=gaps[0, k])
            assert float(ev.wall_time[0, k]) == ref.wall_time
            assert float(ev.energy[0, k]) == ref.energy
            assert float(ev.io_time[0, k]) == ref.io_time
            assert float(ev.work_executed[0, k]) == ref.work_executed
            assert int(ev.n_failures[0, k]) == ref.n_failures
            assert int(ev.n_checkpoints[0, k]) == ref.n_checkpoints

    @pytest.mark.parametrize("proc", PROCESSES, ids=lambda p: p.name)
    def test_tolerance_on_raw_schedule(self, proc):
        """Arbitrary float schedules: closed-form vs accumulated rounding
        differs only in the last few ulps."""
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        gaps = presample_gaps(grid, 6, 128, seed=3, process=proc)
        ev = simulate_trajectories(53.3, grid, T_base=3000.0, gaps=gaps,
                                   engine_kind="event")
        st = simulate_trajectories(53.3, grid, T_base=3000.0, gaps=gaps,
                                   engine_kind="step")
        for name in ("wall_time", "energy", "work_executed", "io_time"):
            np.testing.assert_allclose(getattr(ev, name), getattr(st, name),
                                       rtol=1e-12, err_msg=name)
        np.testing.assert_array_equal(ev.n_failures, st.n_failures)
        np.testing.assert_array_equal(ev.n_checkpoints, st.n_checkpoints)

    def test_parameter_batch_parity(self):
        """Mixed (ckpt, power) batch + per-point dyadic schedules."""
        from repro.sim import get_scenario, grid_from_scenarios
        scens = [get_scenario("fig12", mu_min=120.0),
                 get_scenario("exascale_rho7", mu_min=300.0)]
        grid = grid_from_scenarios(scens)
        rng = np.random.default_rng(5)
        gaps = _dyadic(rng.exponential(1.0, size=(2, 4, 96))
                       * grid.mu[:, None, None])
        T = np.array([40.0, 60.0])
        ev = simulate_trajectories(T, grid, T_base=500.0, gaps=gaps,
                                   engine_kind="event")
        st = simulate_trajectories(T, grid, T_base=500.0, gaps=gaps,
                                   engine_kind="step")
        for name, a in _fields(ev).items():
            np.testing.assert_array_equal(a, getattr(st, name), err_msg=name)

    def test_exhaustion_flags_match_step(self):
        """A schedule that runs dry flags gaps_exhausted identically in
        both kernels (the step kernel's one-draw-per-stretch accounting)."""
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        gaps = np.array([50.0, 70.0])        # far too short for T_base=4000
        ev = simulate_trajectories(60.0, grid, T_base=4000.0, gaps=gaps,
                                   engine_kind="event")
        st = simulate_trajectories(60.0, grid, T_base=4000.0, gaps=gaps,
                                   engine_kind="step")
        assert ev.gaps_exhausted.all() and st.gaps_exhausted.all()
        np.testing.assert_array_equal(ev.wall_time, st.wall_time)

    def test_event_truncates_on_tiny_budget(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        tb = simulate_trajectories(60.0, grid, T_base=50000.0, n_trials=4,
                                   seed=0, n_steps=2, engine_kind="event")
        assert tb.truncated.any()

    def test_unknown_engine_kind_raises(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        with pytest.raises(ValueError, match="engine_kind"):
            simulate_trajectories(60.0, grid, T_base=100.0, n_trials=2,
                                  engine_kind="warp")


class TestDeviceSampling:
    """On-device threefry sampling: determinism + distribution parity."""

    @pytest.mark.parametrize("proc", PROCESSES, ids=lambda p: p.name)
    def test_fixed_seed_determinism(self, proc):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        a = np.asarray(presample_gaps_device(grid, 4, 32, seed=7,
                                             process=proc))
        b = np.asarray(presample_gaps_device(grid, 4, 32, seed=7,
                                             process=proc))
        c = np.asarray(presample_gaps_device(grid, 4, 32, seed=8,
                                             process=proc))
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, c)
        assert (a > 0).all() and np.isfinite(a).all()

    @pytest.mark.parametrize("proc", [Exponential(), Weibull(shape=0.6),
                                      LogNormal(sigma=1.0)],
                             ids=lambda p: p.name)
    def test_device_matches_host_distribution(self, proc):
        """Same distribution as the numpy sampler: mean and CV agree to
        CLT tolerance (different streams by design)."""
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        n = 40_000
        dev = np.asarray(presample_gaps_device(grid, 1, n, seed=0,
                                               process=proc)).ravel()
        host = presample_gaps(grid, 1, n, seed=0, process=proc).ravel()
        cv = float(np.max(np.asarray(proc.gap_cv())))
        tol = 6.0 * cv / math.sqrt(n)
        assert abs(dev.mean() / host.mean() - 1.0) < 2.0 * tol
        assert abs(dev.std() / dev.mean() - cv) < 0.1 * max(cv, 1.0)

    def test_trace_replay_device_rows_are_rotations(self):
        tr = TraceReplay(gaps=[1.0, 2.0, 3.0, 6.0])
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        g = np.asarray(presample_gaps_device(grid, 4, 9, seed=2,
                                             process=TraceReplay(
                                                 gaps=[1.0, 2.0, 3.0, 6.0],
                                                 rescale=False)))[0]
        base = np.array([1.0, 2.0, 3.0, 6.0])
        for row in g:
            assert any(np.allclose(row, np.resize(np.roll(base, -s), 9))
                       for s in range(4)), row
        # rescale=True anchors the replay to the grid's mu
        g2 = np.asarray(presample_gaps_device(grid, 64, 16, seed=2,
                                              process=tr))
        assert g2.mean() == pytest.approx(CK.mu, rel=0.25)

    def test_auto_sampled_trajectories_deterministic(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        kw = dict(T_base=2000.0, n_trials=16, process=Weibull(shape=0.7))
        a = simulate_trajectories(60.0, grid, seed=11, **kw)
        b = simulate_trajectories(60.0, grid, seed=11, **kw)
        c = simulate_trajectories(60.0, grid, seed=12, **kw)
        np.testing.assert_array_equal(a.wall_time, b.wall_time)
        assert not np.array_equal(a.wall_time, c.wall_time)

    def test_host_fallback_for_unknown_process(self):
        """A process without a jax sampler still runs (host numpy gate)."""
        class Odd(Exponential):
            name = "odd"

            def sample_gaps(self, key, size, mean=None):
                raise NotImplementedError("no device sampler")
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        tb = simulate_trajectories(60.0, grid, T_base=1000.0, n_trials=4,
                                   seed=0, process=Odd())
        assert not tb.truncated.any()


class TestBudgetBuckets:
    """Per-point pow2 budgets + bucketed dispatch."""

    def _mixed_grid(self):
        base = ParamGrid.from_params(CK, PW)
        mus = np.array([80.0, 3000.0])       # ~40x failure-rate spread
        return ParamGrid(**{f: (mus if f == "mu"
                                else np.broadcast_to(v, (2,)))
                            for f, v in base.fields().items()})

    def test_budgets_are_per_point_pow2(self):
        grid = self._mixed_grid()
        caps = fail_capacity_points(60.0, grid, 2000.0,
                                    process=Weibull(shape=0.7))
        steps = step_budget_points(60.0, grid, 2000.0,
                                   process=Weibull(shape=0.7))
        for arr in (caps, steps):
            assert arr.shape == (2,)
            assert all((int(v) & (int(v) - 1)) == 0 for v in arr)  # pow2
        # the mixed grid really does split: the fragile point pays more
        assert caps[0] > caps[1]
        assert steps[0] > steps[1]

    def test_budget_knobs_never_change_the_randomness(self):
        """The schedule is sampled once for the whole grid and sliced per
        bucket, so scan-length knobs are PURE performance knobs: explicit
        n_steps (single bucket) and the default bucketed dispatch give
        bit-identical results, and the step kernel consumes the very same
        auto-sampled schedules as the event kernel."""
        grid = self._mixed_grid()
        proc = Weibull(shape=0.7)
        kw = dict(T_base=2000.0, n_trials=8, seed=4, process=proc)
        base = simulate_trajectories(60.0, grid, **kw)          # 2 buckets
        big = simulate_trajectories(60.0, grid, n_steps=8192, **kw)
        for name, a in _fields(base).items():
            np.testing.assert_array_equal(a, getattr(big, name),
                                          err_msg=name)
        st = simulate_trajectories(60.0, grid, engine_kind="step", **kw)
        np.testing.assert_array_equal(base.n_failures, st.n_failures)
        np.testing.assert_allclose(base.wall_time, st.wall_time,
                                   rtol=1e-12)

    def test_array_shape_process_buckets(self):
        """Array-valued Weibull shape: per-point cv feeds per-point
        budgets and the per-bucket process subsets line up."""
        base = ParamGrid.from_params(CK, PW)
        grid = ParamGrid(**{f: np.broadcast_to(v, (3,))
                            for f, v in base.fields().items()})
        proc = Weibull(shape=np.array([0.5, 1.0, 2.0]))
        caps = fail_capacity_points(60.0, grid, 2000.0, process=proc)
        # per-point cv: the k=0.5 row (cv ~ 2.2) pays a larger capacity
        # than the wear-out k=2 row (cv ~ 0.5) — the old np.max would have
        # charged every row the k=0.5 budget
        assert caps[0] > caps[2]
        tb = simulate_trajectories(60.0, grid, T_base=2000.0, n_trials=32,
                                   seed=0, process=proc)
        assert not tb.truncated.any() and not tb.gaps_exhausted.any()


class TestCandidateAxis:
    """simulate_candidates: shared-schedule candidate vmap."""

    def test_matches_per_row_runs(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        gaps = presample_gaps(grid, 6, 128, seed=1, process=Weibull(0.7))
        Ts = np.array([40.0, 60.0, 90.0])
        cand = simulate_candidates(Ts, grid, T_base=2000.0, gaps=gaps)
        assert cand.wall_time.shape == (3, 1, 6)
        for m, T in enumerate(Ts):
            row = simulate_trajectories(T, grid, T_base=2000.0, gaps=gaps)
            np.testing.assert_array_equal(cand.wall_time[m], row.wall_time)
            np.testing.assert_array_equal(cand.energy[m], row.energy)

    def test_grid_shaped_candidates(self):
        base = ParamGrid.from_params(CK, PW)
        grid = ParamGrid(**{f: np.broadcast_to(v, (2,))
                            for f, v in base.fields().items()})
        gaps = presample_gaps(grid, 4, 128, seed=2)
        Ts = np.array([[40.0, 50.0], [60.0, 70.0]])      # (M, B)
        cand = simulate_candidates(Ts, grid, T_base=1000.0, gaps=gaps)
        assert cand.wall_time.shape == (2, 2, 4)
        solo = simulate_trajectories(Ts[1], grid, T_base=1000.0, gaps=gaps)
        np.testing.assert_array_equal(cand.wall_time[1], solo.wall_time)

    def test_mc_surrogate_engines_agree(self):
        """The MC solvers land on the same optimum through either kernel
        (same CRN schedules, same surrogate, different arithmetic path)."""
        sur_e = optimal.MCSurrogate(CK, PW, Weibull(shape=0.7),
                                    T_base=1500.0, n_trials=48, seed=0,
                                    engine_kind="event")
        sur_s = optimal.MCSurrogate(CK, PW, Weibull(shape=0.7),
                                    T_base=1500.0, n_trials=48, seed=0,
                                    engine_kind="step")
        t_e = sur_e.argmin("time")
        t_s = sur_s.argmin("time")
        assert t_e == pytest.approx(t_s, rel=5e-3)

    def test_period_guard(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        with pytest.raises(ValueError, match="period too short"):
            simulate_candidates(np.array([4.0]), grid, T_base=100.0,
                                n_trials=2)

    def test_float32_device_schedule_is_upcast(self):
        """Regression: a schedule parked on device OUTSIDE an x64 context
        arrives float32; the engine must upcast it instead of aborting
        the scan with a carry-dtype error."""
        import jax.numpy as jnp
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        gaps = presample_gaps(grid, 4, 64, seed=0)
        dev = jnp.asarray(gaps)              # jax default config: float32
        got = simulate_trajectories(60.0, grid, T_base=1000.0, gaps=dev)
        want = simulate_trajectories(60.0, grid, T_base=1000.0,
                                     gaps=np.asarray(dev, np.float64))
        np.testing.assert_array_equal(got.wall_time, want.wall_time)


class TestEventEngineStatistics:
    def test_matches_closed_form_model(self):
        """Auto-sampled exponential trajectories agree with the paper's
        first-order expectation at moderate failure rates."""
        from repro.core import model
        ck = CheckpointParams(C=10, R=10, D=1, mu=1000.0, omega=0.5)
        grid = ParamGrid.from_params(ck, PW).reshape((1,))
        tb = simulate_trajectories(60.0, grid, T_base=3000.0,
                                   n_trials=600, seed=0)
        want = float(model.time_final(60.0, ck, 3000.0))
        got = float(tb.wall_time.mean())
        se = float(tb.wall_time.std(ddof=1) / math.sqrt(600))
        assert abs(got - want) < 4.0 * se + 0.01 * want
