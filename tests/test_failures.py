"""Failure-process subsystem: distribution statistics, exponential
bit-for-bit parity with the legacy paths, cross-distribution scalar/batched
parity, exhaustion/raise alignment, and the MC-surrogate solvers."""
import logging
import math

import numpy as np
import pytest

from repro.core import (CheckpointParams, EXASCALE_POWER_RHO55,
                        Exponential, LogNormal, TraceReplay, Weibull,
                        as_process, get_process, fig12_checkpoint,
                        simulate_once, t_opt_time)
from repro.core import optimal
from repro.core.simulator import simulate
from repro.sim import (ParamGrid, ScheduledRNG, get_scenario,
                       simulate_trajectories)
from repro.sim.engine import (default_fail_capacity, default_step_budget,
                              presample_gaps)

CK = fig12_checkpoint(300.0)
PW = EXASCALE_POWER_RHO55


# ---------------------------------------------------------------------------
# Process statistics
# ---------------------------------------------------------------------------

class TestProcessStatistics:
    @pytest.mark.parametrize("proc", [
        Exponential(), Weibull(shape=0.5), Weibull(shape=0.7),
        Weibull(shape=1.3), LogNormal(sigma=0.8), LogNormal(sigma=1.5),
    ])
    def test_sampled_mean_matches_target(self, proc):
        rng = np.random.default_rng(0)
        g = proc.sample(rng, size=(100_000,), mean=250.0)
        # CLT tolerance: 5 sigma of the sample mean.
        cv = float(np.max(np.asarray(proc.gap_cv())))
        assert abs(g.mean() - 250.0) < 5.0 * cv * 250.0 / math.sqrt(g.size)
        assert (g > 0).all()

    @pytest.mark.parametrize("proc", [
        Weibull(shape=0.5), LogNormal(sigma=1.2), Exponential(),
    ])
    def test_empirical_cv_matches_declared(self, proc):
        rng = np.random.default_rng(1)
        g = proc.sample(rng, size=(400_000,), mean=1.0)
        assert g.std() / g.mean() == pytest.approx(
            float(np.asarray(proc.gap_cv())), rel=0.05)

    def test_weibull_shape_one_is_exponential_distribution(self):
        """k = 1 Weibull == exponential distributionally (KS-lite check on
        quantiles), though not stream-for-stream."""
        rng = np.random.default_rng(2)
        g = Weibull(shape=1.0).sample(rng, size=(200_000,), mean=100.0)
        e = Exponential().sample(np.random.default_rng(3), size=(200_000,),
                                 mean=100.0)
        for q in (0.1, 0.5, 0.9, 0.99):
            assert np.quantile(g, q) == pytest.approx(np.quantile(e, q),
                                                      rel=0.05)

    def test_batched_parameter_grid_sampling(self):
        """Array-valued shape: one k per grid row, one mu per grid row."""
        proc = Weibull(shape=np.array([0.5, 1.0, 2.0]))
        mu = np.array([50.0, 200.0, 800.0])[:, None, None]
        g = proc.sample(np.random.default_rng(4), size=(3, 2000, 50),
                        mean=mu)
        means = g.mean(axis=(1, 2))
        cvs = g.std(axis=(1, 2)) / means
        want_cv = proc.gap_cv()
        for i, m in enumerate([50.0, 200.0, 800.0]):
            assert means[i] == pytest.approx(m, rel=0.05)
            assert cvs[i] == pytest.approx(float(want_cv[i]), rel=0.1)

    def test_hazard_shapes(self):
        t = np.array([10.0, 50.0, 200.0])
        h_exp = Exponential().hazard(t, mean=100.0)
        np.testing.assert_allclose(h_exp, 1.0 / 100.0)
        h_w = Weibull(shape=0.5).hazard(t, mean=100.0)
        assert (np.diff(h_w) < 0).all()          # infant mortality
        h_w2 = Weibull(shape=2.0).hazard(t, mean=100.0)
        assert (np.diff(h_w2) > 0).all()         # wear-out
        # Weibull k=1 hazard is the exponential constant.
        np.testing.assert_allclose(Weibull(shape=1.0).hazard(t, mean=100.0),
                                   1.0 / 100.0, rtol=1e-12)

    def test_trace_replay_cycles_and_rescales(self):
        tr = TraceReplay(gaps=[1.0, 2.0, 3.0, 6.0])
        assert tr.mu == pytest.approx(3.0)
        g = tr.sample(np.random.default_rng(5), size=(4, 9))
        # every row is a cyclic rotation of the trace
        base = np.array([1.0, 2.0, 3.0, 6.0])
        for row in g:
            starts = [np.allclose(row, np.resize(np.roll(base, -s), 9))
                      for s in range(4)]
            assert any(starts)
        g2 = tr.sample(np.random.default_rng(5), size=(64, 8), mean=30.0)
        assert g2.mean() == pytest.approx(30.0, rel=0.2)   # rescaled 10x
        assert TraceReplay(gaps=[5.0, 7.0], rescale=False).sample(
            np.random.default_rng(0), size=(2, 4), mean=999.0).max() <= 7.0

    def test_trace_replay_scalar_draws_stay_cyclic(self):
        """Regression: the scalar lazy-draw path must keep the trace's
        ordering (i.i.d. picks would destroy its autocorrelation)."""
        tr = TraceReplay(gaps=[1.0, 2.0, 3.0, 6.0])
        it = tr.iter_gaps(np.random.default_rng(3))
        seq = [next(it) for _ in range(9)]
        base = np.array([1.0, 2.0, 3.0, 6.0])
        assert any(np.allclose(seq, np.resize(np.roll(base, -s), 9))
                   for s in range(4))

    def test_exponential_iter_gaps_matches_legacy_stream(self):
        it = Exponential().iter_gaps(np.random.default_rng(21), mean=300.0)
        legacy = np.random.default_rng(21)
        for _ in range(6):
            assert next(it) == legacy.exponential(300.0)

    def test_registry_and_coercion(self):
        assert isinstance(get_process("weibull", shape=0.6), Weibull)
        assert isinstance(as_process(None), Exponential)
        assert isinstance(as_process("lognormal"), LogNormal)
        with pytest.raises(KeyError):
            get_process("zipf")
        with pytest.raises(ValueError):
            Weibull(shape=0.0)
        with pytest.raises(ValueError):
            TraceReplay(gaps=[])


# ---------------------------------------------------------------------------
# Exponential bit-for-bit parity with the legacy paths
# ---------------------------------------------------------------------------

class TestExponentialBitParity:
    def test_presample_gaps_identical(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        legacy = presample_gaps(grid, 8, 32, seed=7)
        via_process = presample_gaps(grid, 8, 32, seed=7,
                                     process=Exponential())
        np.testing.assert_array_equal(legacy, via_process)

    def test_simulate_once_identical(self):
        r1 = simulate_once(60.0, CK, PW, 2000.0, np.random.default_rng(11))
        r2 = simulate_once(60.0, CK, PW, 2000.0, np.random.default_rng(11),
                           process=Exponential())
        assert r1 == r2

    def test_simulate_trajectories_identical(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        a = simulate_trajectories(60.0, grid, T_base=1000.0, n_trials=4,
                                  seed=3)
        b = simulate_trajectories(60.0, grid, T_base=1000.0, n_trials=4,
                                  seed=3, process=Exponential())
        np.testing.assert_array_equal(a.wall_time, b.wall_time)
        np.testing.assert_array_equal(a.energy, b.energy)
        np.testing.assert_array_equal(a.n_failures, b.n_failures)

    def test_budgets_identical_for_exponential(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        T = np.array([60.0])
        assert default_fail_capacity(T, grid, 2000.0) == \
            default_fail_capacity(T, grid, 2000.0, process=Exponential())
        assert default_step_budget(T, grid, 2000.0) == \
            default_step_budget(T, grid, 2000.0, process=Exponential())


# ---------------------------------------------------------------------------
# Cross-distribution scalar/batched parity (shared schedules)
# ---------------------------------------------------------------------------

class TestCrossDistributionParity:
    @pytest.mark.parametrize("proc", [
        Weibull(shape=0.6), LogNormal(sigma=1.0),
        TraceReplay(gaps=[40.0, 500.0, 120.0, 90.0, 800.0, 33.0]),
    ])
    def test_engine_matches_oracle_under_shared_schedule(self, proc):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        gaps = presample_gaps(grid, 6, 128, seed=9, process=proc)
        tb = simulate_trajectories(60.0, grid, T_base=3000.0, gaps=gaps)
        assert not tb.truncated.any()
        for k in range(gaps.shape[1]):
            ref = simulate_once(60.0, CK, PW, 3000.0,
                                np.random.default_rng(0),
                                gaps=gaps[0, k])
            assert tb.wall_time[0, k] == pytest.approx(ref.wall_time,
                                                       rel=1e-12)
            assert tb.energy[0, k] == pytest.approx(ref.energy, rel=1e-12)
            assert int(tb.n_failures[0, k]) == ref.n_failures
            # the legacy ScheduledRNG replay path agrees too
            ref2 = simulate_once(60.0, CK, PW, 3000.0,
                                 ScheduledRNG(gaps[0, k]))
            assert ref2 == ref

    def test_weibull_means_converge_to_renewal_rate(self):
        """Sanity: realized failure count ~ wall / mu for any renewal
        process with mean mu (renewal theorem)."""
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        tb = simulate_trajectories(60.0, grid, T_base=4000.0, n_trials=200,
                                   seed=0, process=Weibull(shape=0.7))
        assert not tb.truncated.any() and not tb.gaps_exhausted.any()
        rate = tb.n_failures.mean() / tb.wall_time.mean()
        assert rate == pytest.approx(1.0 / CK.mu, rel=0.15)


# ---------------------------------------------------------------------------
# Exhaustion / truncation alignment (bugfix regressions)
# ---------------------------------------------------------------------------

class TestExhaustionAlignment:
    def test_simulate_once_raises_on_exhausted_schedule(self):
        """Regression: a dry schedule used to silently simulate the tail
        failure-free; now mirrors the batched engine's error."""
        with pytest.raises(RuntimeError, match="schedule exhausted"):
            simulate_once(60.0, CK, PW, 4000.0, ScheduledRNG([50.0]))

    def test_simulate_once_raises_on_exhausted_gaps_array(self):
        with pytest.raises(RuntimeError, match="schedule exhausted"):
            simulate_once(60.0, CK, PW, 4000.0, np.random.default_rng(0),
                          gaps=[50.0, 70.0])

    def test_simulate_once_raises_on_event_budget(self):
        """Regression: exceeding max_events must raise, never return a
        partial trajectory as if complete."""
        with pytest.raises(RuntimeError, match="event budget"):
            simulate_once(60.0, CK, PW, 4000.0, np.random.default_rng(0),
                          max_events=10)

    def test_ample_schedule_completes(self):
        r = simulate_once(60.0, CK, PW, 500.0, ScheduledRNG([1e9]))
        assert r.n_failures == 0

    def test_scheduled_rng_contract(self):
        """scale is ignored by contract (gaps replay verbatim); exhaustion
        returns inf once and sets the flag."""
        r = ScheduledRNG([5.0, 7.0])
        assert r.exponential(300.0) == 5.0
        assert r.exponential(1e-9) == 7.0        # scale has no effect
        assert not r.exhausted
        assert math.isinf(r.exponential(300.0))
        assert r.exhausted


# ---------------------------------------------------------------------------
# optimal.py satellites: bracket message + clamp provenance
# ---------------------------------------------------------------------------

class TestOptimalDiagnostics:
    def test_bracket_error_reports_actual_lower_bound(self):
        ck = CheckpointParams(C=10.0, R=10.0, D=1.0, mu=10.0, omega=0.5)
        with pytest.raises(ValueError, match=r"max\(a="):
            optimal._bracket(ck)

    def test_t_opt_time_clamp_flagged_and_logged(self, caplog):
        # omega ~ 1 shrinks a = (1-omega)C, pushing the closed form below
        # the lower bracket edge lo = C: the result is clamped.
        ck = CheckpointParams(C=10.0, R=10.0, D=1.0, mu=300.0, omega=0.99)
        res = optimal.t_opt_time_ex(ck)
        assert res.clamped and res.method == "closed_form"
        assert res.T == pytest.approx(optimal._bracket(ck)[0])
        with caplog.at_level(logging.WARNING, logger="repro.core.optimal"):
            t = t_opt_time(ck)
        assert t == res.T
        assert any("clamped" in r.message for r in caplog.records)

    def test_unclamped_path_has_no_flag(self, caplog):
        res = optimal.t_opt_time_ex(CK)
        assert not res.clamped
        with caplog.at_level(logging.WARNING, logger="repro.core.optimal"):
            t_opt_time(CK)
        assert not caplog.records


# ---------------------------------------------------------------------------
# MC-surrogate solvers
# ---------------------------------------------------------------------------

class TestMCSolvers:
    def test_exponential_surrogate_recovers_closed_form_objective(self):
        """Under the exponential process the MC optimum's simulated wall
        time must match the closed form's within tight MC resolution (the
        objective is flat near T*, so compare values, not argmins)."""
        sur = optimal.MCSurrogate(CK, PW, Exponential(), T_base=3000.0,
                                  n_trials=96, seed=0)
        t_mc = sur.argmin("time")
        t_cf = t_opt_time(CK)
        v = sur([t_mc, t_cf])["time"]
        assert v[0] <= v[1] * (1.0 + 1e-9)       # surrogate argmin wins CRN
        assert v[1] / v[0] < 1.02                # ...by far less than 2%

    def test_weibull_optimum_beats_perturbations_crn(self):
        sur = optimal.MCSurrogate(CK, PW, Weibull(shape=0.7), T_base=3000.0,
                                  n_trials=96, seed=1)
        t_mc = sur.argmin("energy")
        cands = np.clip([t_mc, 0.6 * t_mc, 1.6 * t_mc], sur.lo, sur.hi)
        e = sur(cands)["energy"]
        assert e[0] <= e[1] and e[0] <= e[2]

    def test_evaluate_robustness_point(self):
        from repro.core import evaluate_robustness
        pt = evaluate_robustness(CK, PW, Weibull(shape=0.7), T_base=2500.0,
                                 n_trials=64, seed=0)
        assert pt.T_mc_time > 0 and np.isfinite(pt.time_penalty_exp)
        # CRN pairing guarantees the process optimum is never beaten on the
        # surrogate itself.
        assert pt.time_penalty_exp >= 1.0 - 1e-9
        assert pt.energy_penalty_exp >= 1.0 - 1e-9


# ---------------------------------------------------------------------------
# Robustness scenario family + grid sweep
# ---------------------------------------------------------------------------

class TestRobustnessSweep:
    def test_scenario_registry(self):
        sc = get_scenario("robustness", base="exascale_rho55",
                          process="weibull", shape=0.5, mu_min=200.0)
        assert isinstance(sc.process, Weibull)
        assert sc.ckpt.mu == 200.0
        assert "weibull" in sc.name
        sc2 = get_scenario("robustness", process="trace",
                           trace=[10.0, 20.0])
        assert isinstance(sc2.process, TraceReplay)
        with pytest.raises(ValueError):
            get_scenario("robustness", process="trace")

    def test_small_grid_sweep(self):
        from repro.sim import sweep_weibull_shapes
        res = sweep_weibull_shapes([0.7, 1.0], [300.0], n_trials=48,
                                   seed=0, n_candidates=9, rounds=2)
        assert res.T_mc_time.shape == (2, 1)
        # CRN pairing: the MC optimum is optimal on its own schedules.
        for pen in (res.time_penalty_exp, res.energy_penalty_exp,
                    res.time_penalty_young, res.time_penalty_daly):
            assert (pen >= 1.0 - 1e-9).all()
            assert np.isfinite(pen).all()
        # the k=1 control row: exponential closed forms near-optimal
        assert res.time_penalty_exp[1, 0] < 1.05
        # process means are anchored to the grid's mu, so optima stay in a
        # sane band around the exponential T*.
        assert (res.T_mc_time > res.T_exp_time / 6.0).all()
        assert (res.T_mc_time < res.T_exp_time * 6.0).all()
        # Independent-seed validation entry (the fig5 gate): the reported
        # optima stay near-best among the scored periods on fresh
        # randomness (CRN within the validation run keeps this tight).
        from repro.sim import evaluate_periods_grid
        chk = evaluate_periods_grid(res.grid, res.process, res.eval_periods,
                                    T_base=res.T_base, n_trials=48, seed=5)
        assert chk["wall"].shape == (6, 2, 1)
        assert (chk["wall"][0] <= chk["wall"].min(axis=0) * 1.03).all()
        assert (chk["energy"][1] <= chk["energy"].min(axis=0) * 1.03).all()
