"""Sharded EXECUTION equivalence (not just compile): run the real train and
decode steps on an 8-host-device mesh in a subprocess (device count must be
fixed before jax initializes) and compare against the single-device result.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, r"%(src)s")
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_config, reduced
from repro.models import build
from repro.models.spec import shardings_tree
from repro.optim import adamw
from repro.launch.mesh import make_test_mesh
from repro.parallel import sharding as shd

results = {}
for name in ("starcoder2-3b", "dbrx-132b", "recurrentgemma-9b"):
    cfg = dataclasses.replace(reduced(get_config(name)), head_pad_multiple=4)
    model = build(cfg)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1)
    params = model.init(jax.random.key(0))
    opt = adamw.init_state(params, ocfg)
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (8, 64), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (8, 64), 0,
                                     cfg.vocab_size),
    }
    step = model.make_train_step(ocfg, microbatches=2)

    # single-device reference
    p1, o1, m1 = jax.jit(step)(params, opt, batch)
    ref_loss = float(m1["loss"])
    ref_leaf = np.asarray(jax.tree.leaves(p1)[0], np.float32)

    # 8-device mesh (data x model)
    mesh = make_test_mesh(8)
    pspec = model.param_spec()
    with shd.use_mesh(mesh):
        param_sh = shardings_tree(pspec, mesh)
        params_s = jax.tree.map(jax.device_put, params, param_sh)
        opt_s = adamw.init_state(params_s, ocfg)
        p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch)
        sh_loss = float(m2["loss"])
        sh_leaf = np.asarray(jax.tree.leaves(p2)[0], np.float32)

    results[name] = {
        "ref_loss": ref_loss,
        "sh_loss": sh_loss,
        "leaf_max_diff": float(np.max(np.abs(ref_leaf - sh_leaf))),
        "n_devices": len(jax.devices()),
    }
print(json.dumps(results))
"""


@pytest.fixture(scope="module")
def sharded_results():
    script = SCRIPT % {"src": str(ROOT / "src")}
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1200,
                         cwd=str(ROOT))
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_ran_on_eight_devices(sharded_results):
    assert all(r["n_devices"] == 8 for r in sharded_results.values())


@pytest.mark.parametrize("arch", ["starcoder2-3b", "dbrx-132b",
                                  "recurrentgemma-9b"])
def test_sharded_train_step_matches_single_device(sharded_results, arch):
    r = sharded_results[arch]
    assert r["sh_loss"] == pytest.approx(r["ref_loss"], rel=2e-2), r
    # parameters after one update stay numerically equivalent (bf16 grads,
    # different reduction orders -> loose-but-meaningful bound)
    assert r["leaf_max_diff"] < 5e-2, r
