"""Predicted-vs-measured validation tier: the analytical model against the
*executing* fault-tolerant trainer, in scaled virtual time.

Each scenario runs the real stack (jitted train steps, async sharded-store
checkpoints, buddy replica, policy-driven (T, m)) under an injected failure
schedule and asserts the measured wall-clock / energy lie within a
documented tolerance of ``ml_time_final`` / ``ml_energy_final`` at the
executed operating point (docs/training.md, "Validation recipe").

Tolerances: 12% for exponential injectors, 15% for Weibull (heavier-tailed
gap distribution -> higher seed variance, plus the renewal process's
non-exponential stationary age that the model does not capture).  The
runs here are sized for CI (150 steps x 3 seeds); the deeper 240 x 6
version with tighter margins is ``benchmarks/validate_runtime.py``.
"""
import functools

import numpy as np
import pytest

from repro.ft.run import RunSpec, execute

STEPS = 150
SEEDS = 3
TOL_EXP = 0.12
TOL_WEIBULL = 0.15

_BASE = dict(arch="starcoder2-3b", layers=1, d_model=32, n_heads=2,
             batch=2, seq=16, total_steps=STEPS, step_s=1.0, omega=0.0)
_SL = dict(_BASE, mu_s=15.0, C_s=0.5, R_s=0.5, D_s=0.1, use_buddy=False)
_ML = dict(_BASE, mu_s=15.0, C_s=1.5, R_s=1.5, D_s=0.2, C1_s=0.3,
           R1_s=0.3, D1_s=0.1, q=0.15, profile="paper_ml")
_WEIBULL = dict(process="weibull", process_kwargs={"shape": 0.7})

SCENARIOS = {
    "single_exp": (dict(_SL, strategy="algo_t"), TOL_EXP),
    "single_weibull": (dict(_SL, strategy="algo_t", **_WEIBULL),
                       TOL_WEIBULL),
    "ml_exp": (dict(_ML, strategy="algo_t_ml"), TOL_EXP),
    "ml_weibull": (dict(_ML, strategy="algo_e_ml", **_WEIBULL),
                   TOL_WEIBULL),
    # Async-flush tier (VELOC): the deep write overlaps omega2 of its
    # cost; a failure inside the in-flight window aborts the flush and
    # rolls back to the previous surviving generation — both the runtime
    # (FlushController/discard_in_flight) and the model (per-level w2
    # terms) must price that identically.
    "ml_async_half": (dict(_ML, strategy="algo_t_ml", omega2=0.5),
                      TOL_EXP),
    "ml_async_deep": (dict(_ML, strategy="algo_t_ml", omega2=0.9),
                      TOL_EXP),
}


@functools.lru_cache(maxsize=16)
def run_scenario(name):
    kw, _ = SCENARIOS[name]
    reports = [execute(RunSpec(seed=s, **kw)) for s in range(SEEDS)]
    return reports


def _ratios(reports, key):
    return np.array([r["predicted"][key] for r in reports])


@pytest.mark.parametrize("name", sorted(SCENARIOS))
class TestPredictedVsMeasured:
    def test_completes_under_failures(self, name):
        reports = run_scenario(name)
        for rep in reports:
            assert rep["final_step"] == STEPS
        # the scenario must actually exercise the failure path
        assert sum(r["n_failures"] for r in reports) >= SEEDS

    def test_wall_time_within_tolerance(self, name):
        _, tol = SCENARIOS[name]
        ratios = _ratios(run_scenario(name), "wall_ratio")
        assert abs(ratios.mean() - 1.0) < tol, \
            f"{name}: measured/predicted wall {ratios.mean():.3f}"

    def test_energy_within_tolerance(self, name):
        _, tol = SCENARIOS[name]
        ratios = _ratios(run_scenario(name), "energy_ratio")
        assert abs(ratios.mean() - 1.0) < tol, \
            f"{name}: measured/predicted energy {ratios.mean():.3f}"


class TestOperatingPoint:
    def test_multilevel_policy_chooses_m(self):
        """The (T, m) solver must pick a deepening cadence > 1 when the
        buddy level is an order of magnitude cheaper than the PFS."""
        rep = run_scenario("ml_exp")[0]
        op = rep["operating_point"]
        assert op["deep_every"] > 1
        levels = {c["level"] for c in rep["checkpoints"]}
        assert levels == {1, 2}          # both levels actually written

    def test_single_level_m_is_one(self):
        rep = run_scenario("single_exp")[0]
        assert rep["operating_point"]["deep_every"] == 1
        assert {c["level"] for c in rep["checkpoints"]} == {2}

    def test_realized_period_matches_solved(self):
        """k*s + a must track the solved T (the work-share conversion)."""
        rep = run_scenario("single_exp")[0]
        op = rep["operating_point"]
        assert abs(op["period_realized_s"] - op["period_solved_s"]) \
            <= op["step_s"]

    def test_virtual_costs_reported(self):
        """Scaled time: the manager reports the scenario's virtual C per
        level, not the measured write time."""
        rep = run_scenario("ml_exp")[0]
        for c in rep["checkpoints"]:
            expected = 1.5 if c["level"] == 2 else 0.3
            assert c["C_s"] == expected

    def test_hard_failures_recover_deep(self):
        """q > 0 must produce hard failures that fall back to the PFS."""
        reports = run_scenario("ml_exp")
        n_hard = sum(r["n_hard_failures"] for r in reports)
        assert n_hard >= 1
        for rep in reports:
            assert rep["n_hard_failures"] <= rep["n_failures"]


class TestAsyncFlush:
    def test_flush_window_aborts_happen(self):
        """With omega2 = 0.9 the deep write spends 90% of its cost in
        flight; the fixed failure schedules must interrupt at least one
        flush across the seeds (deterministic given the seeds)."""
        reports = run_scenario("ml_async_deep")
        assert sum(r["flush_aborts"] for r in reports) >= 1
        for rep in reports:
            assert rep["final_step"] == STEPS    # aborts never lose the run

    def test_no_aborts_without_overlap(self):
        """omega = omega2 = 0: every write commits at the end of its
        stall, so there is no in-flight window to interrupt."""
        reports = run_scenario("ml_exp")
        assert all(r["flush_aborts"] == 0 for r in reports)

    def test_aborts_do_not_degrade(self):
        """Failure-interrupt aborts are not store faults: they must not
        trip the consecutive-failure degradation alarm."""
        for rep in run_scenario("ml_async_deep"):
            assert not rep["pfs_degraded"]
            assert rep["alarms"] == []


class TestPredictionBlock:
    def test_prediction_fields(self):
        rep = run_scenario("single_exp")[0]
        pred = rep["predicted"]
        for key in ("wall_s", "energy_j", "wall_ratio", "energy_ratio",
                    "T_used_s", "m", "T_base_s"):
            assert key in pred
        assert pred["T_base_s"] == STEPS * 1.0
        assert pred["wall_s"] > pred["T_base_s"]

    def test_no_prediction_without_failures(self):
        spec = RunSpec(arch="starcoder2-3b", layers=1, d_model=32,
                       n_heads=2, batch=2, seq=16, total_steps=5,
                       step_s=1.0)          # mu = inf
        rep = execute(spec)
        assert rep["predicted"] == {}
        assert rep["n_failures"] == 0
