"""Dry-run machinery tests that work on the single-device pytest process:
HLO cost-walker correctness, collective parsing, input specs, and validation
of the generated dry-run artifacts (skipped when absent)."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, reduced
from repro.configs.base import SHAPES
from repro.launch import hlo_cost
from repro.models import build, input_specs
from repro.optim import adamw

RESULTS = Path(__file__).resolve().parents[1] / "benchmarks" / "results" \
    / "dryrun"


# ---------------------------------------------------------------------------
# HLO cost walker
# ---------------------------------------------------------------------------

SAMPLE_HLO = """HloModule test, is_scheduled=true

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %c = s32[] constant(7)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %ar)
}

ENTRY %main (a: f32[8,8]) -> (s32[], f32[8,8]) {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %a)
  ROOT %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
}
"""


class TestHloCost:
    def test_loop_aware_flops(self):
        cost = hlo_cost.analyze(SAMPLE_HLO)
        # 8x8x8 dot = 2*8*8*8 = 1024 flops, x 7 trips
        assert cost.flops == pytest.approx(1024 * 7)

    def test_loop_aware_collectives(self):
        cost = hlo_cost.analyze(SAMPLE_HLO)
        assert cost.coll_bytes["all-reduce"] == pytest.approx(8 * 8 * 4 * 7)
        assert cost.coll_counts["all-reduce"] == 7

    def test_trip_count_parsing(self):
        comps = hlo_cost.parse_module(SAMPLE_HLO)
        assert hlo_cost._trip_count(comps["cond"]) == 7

    def test_walker_vs_analytic_on_real_compile(self):
        """Compile a tiny train step (1-device) and compare the walker's
        FLOPs against first-principles accounting within 2x."""
        cfg = reduced(get_config("codeqwen1.5-7b"), n_layers=2, d_model=128)
        model = build(cfg)
        ocfg = adamw.AdamWConfig()
        step = model.make_train_step(ocfg)
        params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        opt = jax.eval_shape(lambda: adamw.init_state(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params),
            ocfg))
        batch = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 128), jnp.int32)}
        compiled = jax.jit(step).lower(params, opt, batch).compile()
        cost = hlo_cost.analyze(compiled.as_text())
        N = model.param_count()
        tokens = 4 * 128
        low = 6 * (N - cfg.padded_vocab() * cfg.d_model) * tokens
        high = 14 * N * tokens          # fwd+bwd+remat+attention slack
        assert low * 0.5 < cost.flops < high, (cost.flops, low, high)


# ---------------------------------------------------------------------------
# input_specs
# ---------------------------------------------------------------------------

class TestInputSpecs:
    @pytest.mark.parametrize("arch", [c.name for c in ALL_ARCHS])
    def test_train_specs_shapes(self, arch):
        cfg = get_config(arch)
        spec = input_specs(cfg, SHAPES["train_4k"])
        assert spec["tokens"].shape == (256, 4096)
        assert spec["labels"].dtype == jnp.int32
        if cfg.is_encoder_decoder:
            assert spec["frames"].shape == (256, cfg.encoder_seq,
                                            cfg.d_model)
        if cfg.n_prefix_tokens:
            assert spec["prefix"].shape == (256, cfg.n_prefix_tokens,
                                            cfg.d_model)

    @pytest.mark.parametrize("arch", [c.name for c in ALL_ARCHS])
    def test_decode_specs_have_cache(self, arch):
        cfg = get_config(arch)
        spec = input_specs(cfg, SHAPES["decode_32k"])
        assert spec["token"].shape == (128, 1)
        leaves = jax.tree.leaves(spec["cache"])
        assert leaves, "cache must not be empty"

    def test_sliding_archs_have_bounded_decode_cache(self):
        for name, bound in (("starcoder2-3b", 4096),
                            ("recurrentgemma-9b", 2048)):
            cfg = get_config(name)
            spec = input_specs(cfg, SHAPES["long_500k"])
            kv_lens = {l.shape[-3] for l in jax.tree.leaves(spec["cache"])
                       if hasattr(l, "shape") and len(l.shape) >= 4}
            assert max(kv_lens) <= bound, (name, kv_lens)


# ---------------------------------------------------------------------------
# Generated artifacts (integration — skips when the sweep hasn't run)
# ---------------------------------------------------------------------------

class TestDryRunArtifacts:
    @pytest.fixture(scope="class")
    def records(self):
        files = sorted(RESULTS.glob("*.json"))
        if not files:
            pytest.skip("dry-run artifacts not generated")
        return [json.loads(f.read_text()) for f in files]

    def test_every_applicable_cell_present_on_both_meshes(self, records):
        have = {(r["arch"], r["shape"], r["mesh"]) for r in records}
        for cfg in ALL_ARCHS:
            for shape in cfg.applicable_shapes():
                for mesh in ("pod16x16", "pod2x16x16"):
                    assert (cfg.name, shape.name, mesh) in have, (
                        cfg.name, shape.name, mesh)

    def test_all_fit_hbm(self, records):
        over = [(r["arch"], r["shape"], r["mesh"],
                 r["memory"]["peak_bytes_est"] / 2**30)
                for r in records if not r["fits_hbm"]]
        assert not over, over

    def test_records_have_roofline_inputs(self, records):
        for r in records:
            if "walked" not in r:
                continue
            assert r["walked"]["flops_per_device"] > 0, (r["arch"],
                                                         r["shape"])
            assert r["walked"]["hbm_bytes_per_device"] > 0

    def test_multi_pod_shards_the_pod_axis(self, records):
        """The 512-chip mesh must move bytes across pods for training
        (gradient reduction over 'pod')."""
        trains = [r for r in records
                  if r["shape"] == "train_4k" and "walked" in r]
        by_mesh = {}
        for r in trains:
            by_mesh.setdefault(r["arch"], {})[r["mesh"]] = r
        checked = 0
        for arch, d in by_mesh.items():
            if len(d) == 2:
                multi = d["pod2x16x16"]["walked"]["coll_bytes_total"]
                assert multi > 0
                checked += 1
        assert checked >= 5
