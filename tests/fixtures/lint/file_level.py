# reprolint: disable-file=RPL002 (fixture: whole-file waiver form)
"""disable-file= covers every RPL002 site in the module."""
import functools


@functools.cache
def memo(x):
    return x


@functools.lru_cache(maxsize=None)
def memo_none(x):
    return x
