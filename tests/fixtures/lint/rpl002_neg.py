"""RPL002 negative fixture: bounded, registered caches."""
import functools

from repro.sim.dispatch import LRUCache


@functools.lru_cache(maxsize=64)
def memo_bounded(x):
    return x * x


NAMED = LRUCache(maxsize=8, name="fixture_cache")
