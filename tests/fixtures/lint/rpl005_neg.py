"""RPL005 negative fixture: static shape checks and traced-value
branching through jnp.where are both fine in a scan body."""
import jax.numpy as jnp
from jax import lax


def sweep(xs):
    def body(carry, x):
        if x.shape == ():
            carry = carry + jnp.where(x > 0, x, 0.0)
        return carry, carry

    return lax.scan(body, jnp.zeros((), dtype=jnp.float64), xs)
