"""RPL004 thread-target negative: clean worker bodies stay silent, and a
``target=`` keyword on a non-Thread callee does not root anything."""
import threading


def _tick(n):
    return n + 1


def _host_probe(x):
    return x.item()                 # unreachable: only a non-Thread target


def launch(pool, n):
    threading.Thread(target=_tick, args=(n,), daemon=True).start()
    pool.submit(target=_host_probe)
