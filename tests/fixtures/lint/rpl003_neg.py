"""RPL003 negative fixture: every constructor carries an explicit dtype."""
import jax.numpy as jnp


def make(n):
    a = jnp.zeros(n, dtype=jnp.float64)
    b = jnp.arange(4, dtype=jnp.int32)
    c = jnp.asarray([1.0, 2.0], dtype=jnp.float64)
    d = jnp.ones((2, 2), dtype=jnp.bool_)
    return a, b, c, d
