"""A suppression without a reason: masks its diagnostic but earns RPL006."""
import functools


@functools.cache  # reprolint: disable=RPL002
def memo(x):
    return x
