"""Suppression syntax fixture: a same-line suppression, an own-line
suppression, and an unused one that RPL006 must flag."""
import functools
from functools import lru_cache


@functools.cache  # reprolint: disable=RPL002 (fixture: documented same-line form)
def memo(x):
    return x


# reprolint: disable=RPL002 (fixture: own-line form covers the next line)
memo_none = lru_cache(maxsize=None)


# reprolint: disable=RPL001 (nothing here triggers RPL001 - RPL006 must fire)
def nothing():
    return 0
