"""RPL003 precision-allowance positive fixture: float32 references that
are legal ONLY in the PrecisionPolicy module — linted under a synthetic
sim/ path that is NOT the policy module, every one must flag."""
import jax.numpy as jnp


POLICY_DTYPE = "float32"


def accumulate(x):
    return jnp.asarray(x, jnp.float32)
