"""RPL004 negative fixture: static-argname casts are trace-time Python,
and host syncs in functions no jit can reach are fine."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n",))
def good_step(x, n):
    return jnp.sum(x) * float(n)


def host_report(x):
    return x.item()
