"""RPL004 positive fixture: host syncs inside jit-reachable functions —
three directly in a jitted def, one in a helper reached through the
call graph."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def bad_step(x):
    total = jnp.sum(x)
    host = total.item()
    arr = np.asarray(x)
    return float(total) + host, arr


def helper(y):
    return y.tolist()


@jax.jit
def calls_helper(y):
    return helper(y)
