"""RPL002 positive fixture: three unbounded/unregistered caches, plus a
module-level dict cache that only counts under a src/ path."""
import functools

from repro.sim.dispatch import LRUCache


@functools.cache
def memo_unbounded(x):
    return x * x


@functools.lru_cache(maxsize=None)
def memo_none(x):
    return x + 1


ANON = LRUCache(maxsize=8)

_RESULT_CACHE = {}
