"""RPL003 positive fixture (linted under a synthetic src/repro/sim/
path): dtype-less constructors and float32 in the f64 subsystems."""
import jax.numpy as jnp


def make(n):
    a = jnp.zeros(n)
    b = jnp.arange(4)
    c = jnp.asarray([1.0, 2.0])
    d = jnp.ones(3, jnp.float32)
    e = "float32"
    return a, b, c, d, e
