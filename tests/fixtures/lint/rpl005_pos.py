"""RPL005 positive fixture: Python control flow on traced values inside
a lax.scan body."""
import jax.numpy as jnp
from jax import lax


def sweep(xs):
    def body(carry, x):
        if x > 0:
            carry = carry + x
        while carry > 10:
            carry = carry - 1
        return carry, carry

    return lax.scan(body, jnp.zeros((), dtype=jnp.float64), xs)
