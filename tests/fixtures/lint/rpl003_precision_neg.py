"""RPL003 precision-allowance negative fixture: the same float32
references linted under the PrecisionPolicy module path
(src/repro/sim/precision.py) are clean — that module is the one legal
home for reduced-precision dtypes.  The explicit-dtype constructor check
still applies there, so the constructors below spell their dtypes."""
import jax.numpy as jnp


POLICY_DTYPE = "float32"


def cast(x):
    return jnp.asarray(x, jnp.float32)


def zero_like_policy(n):
    return jnp.zeros(n, dtype=jnp.float32)
