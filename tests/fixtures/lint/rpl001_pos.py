"""RPL001 positive fixture: four nondeterministic-randomness sites."""
import random
import time

import jax
import numpy as np


def draws():
    rng = np.random.default_rng()                  # unseeded: OS entropy
    noise = np.random.normal(size=3)               # global-state numpy RNG
    key = jax.random.PRNGKey(int(time.time()))     # wall-clock seed
    jitter = random.random()                       # stdlib global state
    return rng, noise, key, jitter
