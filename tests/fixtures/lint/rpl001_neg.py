"""RPL001 negative fixture: explicit seeds, threaded keys, generator
methods.  Clean under a tests/ path; under a synthetic src/ path the
seeded constructor becomes the one "outside approved sites" violation.
"""
import jax
import numpy as np


def draws(seed):
    rng = np.random.default_rng(1234)
    key = jax.random.PRNGKey(seed)
    vals = rng.normal(size=3)
    return key, vals
