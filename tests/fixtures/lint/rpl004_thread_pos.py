"""RPL004 thread-target fixture: worker bodies handed to
``threading.Thread(target=...)`` are call-graph roots — both a plain
function and the ``target=self._method`` class shape."""
import threading

import numpy as np


def _flush_body(buf):
    return np.asarray(buf)          # host pull inside the worker


class _Controller:
    def _drain(self, x):
        return x.item()             # device->host sync in the worker

    def start(self, x):
        t = threading.Thread(target=self._drain, args=(x,), daemon=True)
        t.start()
        return t


def spawn(buf):
    return threading.Thread(target=_flush_body, args=(buf,), daemon=True)
