"""Optimizer + data-pipeline unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLM, DataConfig, for_arch
from repro.configs import get_config, reduced
from repro.optim import adamw
from repro.optim.adamw import FactoredV


def quad_params():
    return {"w": jnp.ones((16, 32)), "b": jnp.zeros((32,))}


def quad_loss(p, x):
    y = x @ p["w"] + p["b"]
    return jnp.mean(y ** 2)


class TestAdamW:
    def test_minimizes_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.05, warmup_steps=1, total_steps=1000,
                                weight_decay=0.0)
        p = quad_params()
        st = adamw.init_state(p, cfg)
        x = jax.random.normal(jax.random.key(0), (64, 16))
        losses = []
        for _ in range(50):
            loss, g = jax.value_and_grad(quad_loss)(p, x)
            p, st, _ = adamw.apply_updates(cfg, p, g, st)
            losses.append(float(loss))
        assert losses[-1] < 0.02 * losses[0]

    def test_factored_matches_full_direction(self):
        """Factored-v updates point the same general direction as full-v."""
        cfg_full = adamw.AdamWConfig(lr=0.01, warmup_steps=1,
                                     weight_decay=0.0)
        cfg_fact = adamw.AdamWConfig(lr=0.01, warmup_steps=1,
                                     weight_decay=0.0,
                                     factored_second_moment=True)
        p = quad_params()
        x = jax.random.normal(jax.random.key(1), (64, 16))
        _, g = jax.value_and_grad(quad_loss)(p, x)
        p1, _, _ = adamw.apply_updates(cfg_full, p, g,
                                       adamw.init_state(p, cfg_full))
        p2, _, _ = adamw.apply_updates(cfg_fact, p, g,
                                       adamw.init_state(p, cfg_fact))
        d1 = np.asarray(p1["w"] - p["w"]).ravel()
        d2 = np.asarray(p2["w"] - p["w"]).ravel()
        cos = d1 @ d2 / (np.linalg.norm(d1) * np.linalg.norm(d2))
        assert cos > 0.9

    def test_factored_state_is_small(self):
        cfg = adamw.AdamWConfig(factored_second_moment=True,
                                momentum_dtype="bfloat16")
        p = {"w": jnp.zeros((1024, 1024), jnp.bfloat16)}
        st = adamw.init_state(p, cfg)
        assert isinstance(st.v["w"], FactoredV)
        v_bytes = st.v["w"].row.nbytes + st.v["w"].col.nbytes
        assert v_bytes < 0.01 * (1024 * 1024 * 4)
        assert st.m["w"].dtype == jnp.bfloat16
        assert st.master["w"].dtype == jnp.float32

    def test_master_weights_precision(self):
        """bf16 params with f32 master accumulate small updates that bf16
        alone would lose."""
        cfg = adamw.AdamWConfig(lr=1e-4, warmup_steps=1, weight_decay=0.0)
        p = {"w": jnp.ones((8, 8), jnp.bfloat16) * 100.0}
        st = adamw.init_state(p, cfg)
        g = {"w": jnp.full((8, 8), 1e-3, jnp.bfloat16)}
        master0 = np.asarray(st.master["w"]).copy()
        for _ in range(10):
            p, st, _ = adamw.apply_updates(cfg, p, g, st)
        # the f32 master strictly decreased even though each step is far
        # below bf16 resolution at magnitude 100
        assert (np.asarray(st.master["w"]) < master0).all()
        assert float(master0.max() - np.asarray(st.master["w"]).max()) < 0.5

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_ratio=0.1)
        lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in
               (0, 5, 10, 55, 100, 1000)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert lrs[3] < 1.0
        assert lrs[4] == pytest.approx(0.1, rel=1e-3)
        assert lrs[5] == pytest.approx(0.1, rel=1e-3)

    def test_grad_clip_scales_update(self):
        cfg = adamw.AdamWConfig(lr=0.1, grad_clip=1e-3, warmup_steps=1,
                                weight_decay=0.0)
        p = quad_params()
        g = {"w": jnp.full((16, 32), 100.0), "b": jnp.zeros((32,))}
        _, _, metrics = adamw.apply_updates(cfg, p, g,
                                            adamw.init_state(p, cfg))
        assert float(metrics["grad_norm"]) > 1e3


class TestData:
    def test_state_roundtrip(self):
        d = SyntheticLM(DataConfig(vocab_size=100, batch=2, seq_len=8,
                                   seed=3))
        next(d)
        next(d)
        st = d.state()
        b1 = np.asarray(next(d)["tokens"])
        d2 = SyntheticLM(DataConfig(vocab_size=100, batch=2, seq_len=8,
                                    seed=3))
        d2.restore(st)
        b2 = np.asarray(next(d2)["tokens"])
        np.testing.assert_array_equal(b1, b2)

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLM(DataConfig(vocab_size=100, batch=2, seq_len=8,
                                   seed=0))
        b = next(d)
        assert b["tokens"].shape == b["labels"].shape

    def test_modality_stubs(self):
        cfg = reduced(get_config("whisper-tiny"))
        d = for_arch(cfg, batch=2, seq_len=16)
        b = next(d)
        assert b["frames"].shape == (2, cfg.encoder_seq, cfg.d_model)
        cfg = reduced(get_config("internvl2-1b"))
        d = for_arch(cfg, batch=2, seq_len=16)
        b = next(d)
        assert b["prefix"].shape == (2, cfg.n_prefix_tokens, cfg.d_model)

    def test_seed_mismatch_raises(self):
        d = SyntheticLM(DataConfig(vocab_size=10, batch=1, seq_len=4,
                                   seed=1))
        with pytest.raises(AssertionError):
            d.restore({"step": 0, "seed": 2})


class TestGradCompression:
    def test_wire_ratio_and_error_feedback(self):
        from repro.optim import grad_compress as gc
        g = {"w": jax.random.normal(jax.random.key(0), (256, 512))}
        st = gc.init_state(g)
        b1, st, stats = gc.compress_grads(g, st, force_interpret=True)
        assert stats["ratio"] < 0.3                    # ~4x compression
        b2, st, _ = gc.compress_grads(g, st, force_interpret=True)
        e1 = float(jnp.max(jnp.abs(b1["w"] - g["w"])))
        tele = float(jnp.max(jnp.abs((b1["w"] + b2["w"]) / 2 - g["w"])))
        assert tele < 0.75 * e1                        # residual telescopes

    def test_training_with_compression_converges(self):
        from repro.optim import grad_compress as gc
        cfg = adamw.AdamWConfig(lr=0.05, warmup_steps=1, weight_decay=0.0)
        p = quad_params()
        st = adamw.init_state(p, cfg)
        cst = gc.init_state(p)
        x = jax.random.normal(jax.random.key(0), (64, 16))
        losses = []
        for _ in range(50):
            loss, g = jax.value_and_grad(quad_loss)(p, x)
            g, cst, _ = gc.compress_grads(g, cst, force_interpret=True)
            p, st, _ = adamw.apply_updates(cfg, p, g, st)
            losses.append(float(loss))
        assert losses[-1] < 0.05 * losses[0]

    def test_tiny_leaves_pass_through(self):
        from repro.optim import grad_compress as gc
        g = {"b": jnp.ones((8,))}
        st = gc.init_state(g)
        back, _, stats = gc.compress_grads(g, st, force_interpret=True)
        np.testing.assert_array_equal(np.asarray(back["b"]),
                                      np.asarray(g["b"]))
