"""Advisor-service tests: batching/caching semantics, the quantization
tolerance contract, admission batching, and the serving CLIs.

The load-bearing guarantees (ISSUE 6 acceptance criteria):
  * batched == sequential answers, bit-identical at fixed seed;
  * cache hits serve within the documented quantization tolerance of an
    exact per-request solve (time, energy, and multilevel (T, m));
  * a burst of distinct requests is answered in ONE dispatched solve;
  * `--reduce/--no-reduce` actually toggles (the old store_true+default
    bug), and the advisor CLI smoke leg passes.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.serve import (AdvisorService, AdviceRequest, Quantization,
                         StoreTier, ThreadedAdvisor, exact_fingerprint,
                         fingerprint, quantize_request, run_open_loop,
                         synthetic_requests)
from repro.sim import cache_stats, reset_cache_stats
from repro.sim import sweep as sweep_mod

QUANT = Quantization()          # the documented defaults


def _same_advice(a, b) -> bool:
    """Bitwise equality of the served numbers (NaN == NaN)."""
    def eq(x, y):
        return x == y or (isinstance(x, float) and math.isnan(x)
                          and math.isnan(y))
    return (eq(a.period, b.period) and a.deep_every == b.deep_every
            and a.store == b.store
            and eq(a.predicted_wall, b.predicted_wall)
            and eq(a.predicted_energy, b.predicted_energy)
            and eq(a.T_time, b.T_time) and eq(a.T_energy, b.T_energy)
            and a.m_time == b.m_time and a.m_energy == b.m_energy)


def _mixed_workload(n=48, seed=7, repeat_frac=0.25):
    return synthetic_requests(n, seed=seed, two_tier_frac=0.5,
                              repeat_frac=repeat_frac)


class TestBatchingSemantics:
    def test_batched_equals_sequential_bit_identical(self):
        reqs = _mixed_workload()
        batched = AdvisorService(cache_name=None).advise_many(reqs)
        solo = AdvisorService(cache_name=None)
        for req, a in zip(reqs, batched):
            assert _same_advice(a, solo.advise(req)), req

    def test_burst_of_distinct_requests_is_one_dispatched_solve(self):
        reqs = synthetic_requests(64, seed=5, two_tier_frac=0.0)
        svc = AdvisorService(cache_name=None)
        svc.advise_many(reqs)
        assert svc.metrics()["dispatched_solves"] == 1

    def test_mixed_shapes_take_one_solve_per_shape(self):
        reqs = _mixed_workload(repeat_frac=0.0)
        assert {r.is_multilevel for r in reqs} == {False, True}
        svc = AdvisorService(cache_name=None)
        svc.advise_many(reqs)
        assert svc.metrics()["dispatched_solves"] == 2

    def test_heterogeneous_cadence_caps_batch_and_match_solo(self):
        base = synthetic_requests(6, seed=13, two_tier_frac=1.0)
        reqs = [dataclasses.replace(r, max_deep_every=cap)
                for r, cap in zip(base, (1, 2, 3, 5, 8, 12))]
        batched = AdvisorService(cache_name=None).advise_many(reqs)
        solo = AdvisorService(cache_name=None)
        for req, a in zip(reqs, batched):
            assert a.m_time <= req.max_deep_every
            assert a.m_energy <= req.max_deep_every
            assert _same_advice(a, solo.advise(req)), req

    def test_deep_every_one_recommends_deep_tier_only(self):
        req = next(r for r in synthetic_requests(32, seed=2,
                                                 two_tier_frac=1.0))
        req = dataclasses.replace(req, max_deep_every=1)
        adv = AdvisorService(cache_name=None).advise(req)
        assert adv.deep_every == 1
        assert adv.store == req.deep.name

    def test_t_base_scales_predictions_not_period(self):
        svc = AdvisorService(cache_name=None)
        req = synthetic_requests(1, seed=21)[0]
        a1 = svc.advise(dataclasses.replace(req, T_base=1.0))
        a9 = svc.advise(dataclasses.replace(req, T_base=9.0))
        assert a9.period == a1.period
        assert a9.deep_every == a1.deep_every
        assert a9.predicted_wall == pytest.approx(9.0 * a1.predicted_wall)
        assert a9.predicted_energy == pytest.approx(
            9.0 * a1.predicted_energy)


class TestFingerprintCache:
    def test_fingerprint_ignores_objective_t_base_and_names(self):
        req = synthetic_requests(1, seed=3, two_tier_frac=1.0)[0]
        fp = fingerprint(req, QUANT)
        assert fingerprint(dataclasses.replace(req, objective="time"),
                           QUANT) == fp
        assert fingerprint(dataclasses.replace(req, T_base=123.0),
                           QUANT) == fp
        renamed = dataclasses.replace(
            req, tiers=tuple(dataclasses.replace(t, name=f"x{i}")
                             for i, t in enumerate(req.tiers)))
        assert fingerprint(renamed, QUANT) == fp

    def test_fingerprint_distinguishes_cadence_cap_and_process(self):
        req = synthetic_requests(1, seed=3, two_tier_frac=1.0)[0]
        fp = fingerprint(req, QUANT)
        assert fingerprint(dataclasses.replace(req, max_deep_every=3),
                           QUANT) != fp
        assert fingerprint(dataclasses.replace(req, process="weibull",
                                               process_param=0.7),
                           QUANT) != fp

    def test_quantize_is_idempotent(self):
        for req in synthetic_requests(8, seed=4, two_tier_frac=0.5):
            qr = quantize_request(req, QUANT)
            assert quantize_request(qr, QUANT) == qr
            assert fingerprint(qr, QUANT) == fingerprint(req, QUANT)

    def test_repeat_workload_hits_and_skips_solves(self):
        reqs = _mixed_workload(repeat_frac=0.0)
        svc = AdvisorService(cache_name=None)
        first = svc.advise_many(reqs)
        solves = svc.metrics()["dispatched_solves"]
        again = svc.advise_many(reqs)
        m = svc.metrics()
        assert m["dispatched_solves"] == solves      # all hits, no solve
        assert all(a.cache_hit for a in again)
        assert not any(a.cache_hit for a in first)
        for a, b in zip(first, again):
            assert _same_advice(a, b)
        fc = m["fingerprint_cache"]
        assert fc["hits"] >= len(reqs)
        assert fc["inserts"] == fc["size"] == len(
            {fingerprint(r, svc.quant) for r in reqs})

    def test_uncertifiable_cell_falls_back_to_exact_solve(self):
        # A coarse lattice (50% steps) cannot certify the tolerance, so
        # every answer must come from the exact-parameter path and match
        # the unquantized service bit for bit.
        coarse = Quantization(rel=0.5, absolute=0.25, tol=1e-2)
        reqs = _mixed_workload(n=12, repeat_frac=0.0)
        svc = AdvisorService(quantization=coarse, cache_name=None)
        exact = AdvisorService(quantization=Quantization(rel=0.0,
                                                         absolute=0.0),
                               cache_name=None)
        for a, req in zip(svc.advise_many(reqs), reqs):
            assert a.exact and a.cert_bound == 0.0
            assert _same_advice(a, exact.advise(req)), req
        assert svc.metrics()["fallback_requests"] == len(reqs)
        # identical repeats hit the zero-width exact entries
        again = svc.advise_many(reqs)
        assert all(a.cache_hit for a in again)

    def test_eviction_changes_no_answers(self):
        reqs = synthetic_requests(10, seed=17, two_tier_frac=0.0)
        big = AdvisorService(cache_name=None)
        tiny = AdvisorService(cache_size=2, cache_name=None)
        ref = big.advise_many(reqs)
        for _ in range(2):              # thrash the 2-entry cache
            for req, a in zip(reqs, tiny.advise_many(reqs)):
                pass
        for req, want in zip(reqs, ref):
            assert _same_advice(tiny.advise(req), want)
        assert tiny.metrics()["fingerprint_cache"]["evictions"] > 0


def _objective_values(req, period, deep_every):
    """Host closed-form (time, energy) of ``req`` at a served point."""
    if req.is_multilevel:
        ck, pw = req.multilevel_params()
        p = {"C1": ck.C1, "R1": ck.R1, "D1": ck.D1, "C2": ck.C2,
             "R2": ck.R2, "D2": ck.D2, "mu": ck.mu, "q": ck.q,
             "omega": ck.omega, "P_static": pw.P_static,
             "P_cal": pw.P_cal, "P_io1": pw.P_io1, "P_io2": pw.P_io2,
             "P_down": pw.P_down}
        m = float(deep_every)
        return (float(sweep_mod.ml_time_final_batched(period, m, p,
                                                      req.T_base)),
                float(sweep_mod.ml_energy_final_batched(period, m, p,
                                                        req.T_base)))
    ck, pw = req.single_params()
    p = {"C": ck.C, "R": ck.R, "D": ck.D, "mu": ck.mu, "omega": ck.omega,
         "P_static": pw.P_static, "P_cal": pw.P_cal, "P_io": pw.P_io,
         "P_down": pw.P_down}
    return (float(sweep_mod.time_final_batched(period, p, req.T_base)),
            float(sweep_mod.energy_final_batched(period, p, req.T_base)))


class TestQuantizationTolerance:
    """The documented contract: served objective within tol of exact.

    Seeded-random sweep over the synthetic platform distribution (single
    AND two-tier, both objectives); the hypothesis-driven variant lives
    in tests/test_property.py.
    """

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_served_objective_within_documented_tolerance(self, seed):
        reqs = synthetic_requests(64, seed=seed, two_tier_frac=0.5)
        quant = AdvisorService(cache_name=None)          # default lattice
        exact = AdvisorService(quantization=Quantization(rel=0.0,
                                                         absolute=0.0),
                               cache_name=None)
        served = quant.advise_many(reqs)
        truth = exact.advise_many(reqs)
        checked = 0
        for req, a, t in zip(reqs, served, truth):
            if not (a.valid and t.valid):
                continue
            if not a.exact:
                assert a.cert_bound <= quant.quant.tol
            # both objectives, each at ITS served operating point
            sv_t, _ = _objective_values(req, a.T_time, a.m_time)
            _, sv_e = _objective_values(req, a.T_energy, a.m_energy)
            op_t, _ = _objective_values(req, t.T_time, t.m_time)
            _, op_e = _objective_values(req, t.T_energy, t.m_energy)
            slack = max(a.cert_bound, 1e-12)
            assert sv_t <= op_t * (1.0 + slack), (req, sv_t, op_t)
            assert sv_e <= op_e * (1.0 + slack), (req, sv_e, op_e)
            checked += 1
        assert checked >= len(reqs) // 2     # the sweep must have teeth

    def test_cert_bound_is_conservative_for_cell_members(self):
        # Perturb each request within its own lattice cell: the exact
        # re-solve of the perturbed platform may improve on the served
        # answer by at most cert_bound.
        rng = np.random.default_rng(0)
        reqs = synthetic_requests(24, seed=9, two_tier_frac=0.5)
        svc = AdvisorService(cache_name=None)
        exact = AdvisorService(quantization=Quantization(rel=0.0,
                                                         absolute=0.0),
                               cache_name=None)
        served = svc.advise_many(reqs)
        for req, a in zip(reqs, served):
            if not a.valid or a.exact:
                continue
            # Perturb the cell's REPRESENTATIVE by under half a lattice
            # step, so the perturbed platform provably stays in the cell.
            rep = quantize_request(req, svc.quant)
            f = 1.0 + (rng.uniform(-0.49, 0.49) * svc.quant.rel)
            pert = dataclasses.replace(
                rep, mu=rep.mu * f, T_base=req.T_base,
                tiers=tuple(dataclasses.replace(t, C=t.C * f)
                            for t in rep.tiers))
            assert fingerprint(pert, svc.quant) == fingerprint(req,
                                                               svc.quant)
            b = svc.advise(pert)
            assert b.cache_hit and _same_advice(a, b)
            t = exact.advise(pert)
            if not t.valid:
                continue
            sv_t, _ = _objective_values(pert, b.T_time, b.m_time)
            _, sv_e = _objective_values(pert, b.T_energy, b.m_energy)
            op_t, _ = _objective_values(pert, t.T_time, t.m_time)
            _, op_e = _objective_values(pert, t.T_energy, t.m_energy)
            assert sv_t <= op_t * (1.0 + a.cert_bound + 1e-12)
            assert sv_e <= op_e * (1.0 + a.cert_bound + 1e-12)


class TestThreadedAdvisor:
    def test_concurrent_submissions_match_direct_service(self):
        reqs = _mixed_workload(n=32, repeat_frac=0.3)
        want = AdvisorService(cache_name=None).advise_many(reqs)
        with ThreadedAdvisor(AdvisorService(cache_name=None),
                             batch_window_s=5e-3) as advisor:
            futs = [advisor.submit(r) for r in reqs]
            got = [f.result(timeout=60) for f in futs]
            m = advisor.metrics()
        assert m["windows"] >= 1
        assert m["requests"] == len(reqs)
        for a, b in zip(want, got):
            assert _same_advice(a, b)

    def test_zero_window_still_serves(self):
        req = synthetic_requests(1, seed=1)[0]
        with ThreadedAdvisor(AdvisorService(cache_name=None),
                             batch_window_s=0.0) as advisor:
            assert advisor.advise(req).period > 0

    def test_close_is_idempotent_and_rejects_new_work(self):
        advisor = ThreadedAdvisor(AdvisorService(cache_name=None))
        advisor.close()
        advisor.close()
        with pytest.raises(RuntimeError):
            advisor.submit(synthetic_requests(1, seed=1)[0])

    def test_invalid_knobs_rejected(self):
        with pytest.raises(ValueError):
            ThreadedAdvisor(AdvisorService(cache_name=None),
                            batch_window_s=-1.0)
        with pytest.raises(ValueError):
            ThreadedAdvisor(AdvisorService(cache_name=None), max_batch=0)


class TestLoadGenerator:
    def test_open_loop_reports_throughput_and_hits(self):
        reqs = synthetic_requests(40, seed=9, two_tier_frac=0.5,
                                  repeat_frac=0.5)
        with ThreadedAdvisor(AdvisorService(cache_name=None),
                             batch_window_s=2e-3) as advisor:
            rep = run_open_loop(advisor, reqs, rate_hz=2000.0,
                                warmup=synthetic_requests(8, seed=10))
        assert rep.n == 40 and rep.rps > 0.0
        assert rep.hit_rate > 0.0            # repeated workload must hit
        assert 0.0 <= rep.p50_ms <= rep.p99_ms <= rep.max_ms
        assert rep.windows >= 1
        assert rep.summary()["rps"] == rep.rps

    def test_synthetic_requests_deterministic_and_shaped(self):
        a = synthetic_requests(32, seed=6, two_tier_frac=0.5,
                               repeat_frac=0.25)
        b = synthetic_requests(32, seed=6, two_tier_frac=0.5,
                               repeat_frac=0.25)
        assert a == b
        assert any(r.is_multilevel for r in a)
        assert any(not r.is_multilevel for r in a)
        fps = [fingerprint(r, QUANT) for r in a]
        assert len(set(fps)) < len(fps)      # repeat_frac produced dups


class TestSchemaValidation:
    def test_rejects_bad_requests(self):
        tier = StoreTier(name="pfs", C=60.0, R=60.0, D=0.0, P_io=10.0)
        with pytest.raises(ValueError):
            AdviceRequest(mu=0.0, tiers=(tier,))
        with pytest.raises(ValueError):
            AdviceRequest(mu=100.0, tiers=())
        with pytest.raises(ValueError):
            AdviceRequest(mu=100.0, tiers=(tier, tier, tier))
        with pytest.raises(ValueError):
            AdviceRequest(mu=100.0, tiers=(tier,), objective="carbon")
        with pytest.raises(ValueError):
            AdviceRequest(mu=100.0, tiers=(tier,), T_base=-1.0)
        with pytest.raises(ValueError):
            AdviceRequest(mu=100.0, tiers=(tier,), max_deep_every=0)
        with pytest.raises(ValueError):
            StoreTier(name="bad", C=-1.0, R=0.0, D=0.0, P_io=0.0)
        with pytest.raises(ValueError):
            StoreTier(name="bad", C=1.0, R=0.0, D=0.0, P_io=0.0, q=1.5)

    def test_exact_fingerprint_zero_width(self):
        req = synthetic_requests(1, seed=1)[0]
        assert exact_fingerprint(req) != exact_fingerprint(
            dataclasses.replace(req, mu=req.mu * (1.0 + 1e-12)))


class TestCacheStatsRegistry:
    def test_named_caches_expose_counters(self):
        reset_cache_stats()
        svc = AdvisorService(cache_name="serve.fingerprints")
        reqs = synthetic_requests(8, seed=14, two_tier_frac=0.0)
        svc.advise_many(reqs)
        svc.advise_many(reqs)
        stats = cache_stats()
        assert "dispatch.runners" in stats
        assert "engine.device_samplers" in stats
        assert "engine.ml_runners" in stats
        fp = stats["serve.fingerprints"]
        assert fp["hits"] > 0 and fp["inserts"] > 0
        assert fp["lookups"] == fp["hits"] + fp["misses"]
        assert svc.metrics()["caches"]["serve.fingerprints"][
            "hits"] == fp["hits"]
        reset_cache_stats()
        assert cache_stats()["serve.fingerprints"]["lookups"] == 0

    def test_runner_cache_counts_hits_across_calls(self):
        reset_cache_stats()
        from repro.sim import evaluate_grid
        from repro.sim.scenarios import mu_rho_grid
        grid = mu_rho_grid([300.0, 600.0], [2.0, 5.0])
        evaluate_grid(grid)
        evaluate_grid(grid)
        runners = cache_stats()["dispatch.runners"]
        assert runners["lookups"] >= 2
        assert runners["hits"] >= 1


class TestServeCLI:
    def test_reduce_flag_can_be_disabled(self):
        from repro.launch.serve import build_parser
        assert build_parser().parse_args([]).reduce is True
        assert build_parser().parse_args(["--reduce"]).reduce is True
        assert build_parser().parse_args(["--no-reduce"]).reduce is False

    def test_advisor_parser_defaults(self):
        from repro.launch.serve import build_advisor_parser
        args = build_advisor_parser().parse_args([])
        assert args.requests == 512 and not args.smoke
        args = build_advisor_parser().parse_args(
            ["--smoke", "--rate", "500", "--repeat-frac", "0.5"])
        assert args.smoke and args.rate == 500.0

    def test_advisor_smoke_leg_passes(self):
        from repro.launch.serve import main
        rep = main(["advisor", "--smoke"])
        assert rep.rps > 0.0 and rep.hit_rate > 0.0


class TestAdvisorBenchGate:
    def test_committed_baseline_gates_advisor_rps(self):
        import json
        from pathlib import Path
        from benchmarks.bench_sweep import CANONICAL, check_regression
        baseline = json.loads(Path(CANONICAL).read_text())
        entry = baseline["advisor_rps"]
        assert entry["speedup_warm"] >= 20.0         # acceptance floor
        assert entry["n_requests"] == 512
        assert {"rps", "p50_ms", "p99_ms"} <= set(entry)
        assert not entry.get("ungated")
        assert baseline["advisor_load_regimes"].get("ungated")
        # self-comparison passes; a 20x advisor regression trips the gate
        assert check_regression(baseline, baseline) == []
        bad = json.loads(json.dumps(baseline))
        bad["advisor_rps"]["speedup_warm"] = entry["speedup_warm"] / 20.0
        assert any("advisor_rps" in r
                   for r in check_regression(baseline, bad))
