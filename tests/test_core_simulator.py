"""Monte-Carlo simulator vs closed-form expectations, and policy behaviour."""
import numpy as np
import pytest

from repro.core import (CheckpointParams, EXASCALE_POWER_RHO55,
                        simulate, simulate_once, t_opt_time, t_opt_energy,
                        CheckpointPolicy, PolicyConfig)
from repro.core import model


CK = CheckpointParams(C=10.0, R=10.0, D=1.0, mu=300.0, omega=0.5)
PW = EXASCALE_POWER_RHO55


class TestSimulatorVsModel:
    """The first-order model should match simulation to a few percent in its
    validity regime (C, D, R << mu)."""

    @pytest.mark.parametrize("T", [40.0, 53.3, 90.0, 128.0])
    def test_wall_time_matches(self, T):
        sim = simulate(T, CK, PW, T_base=4000.0, n_trials=400, seed=0)
        pred = float(model.time_final(T, CK, 4000.0))
        # allow 3% model bias + 3 standard errors
        tol = 0.03 * pred + 3.0 * sim["T_final_se"]
        assert abs(sim["T_final"] - pred) < tol

    @pytest.mark.parametrize("T", [40.0, 53.3, 128.0])
    def test_energy_matches(self, T):
        sim = simulate(T, CK, PW, T_base=4000.0, n_trials=400, seed=1)
        pred = float(model.energy_final(T, CK, PW, 4000.0))
        tol = 0.03 * pred + 3.0 * sim["E_final_se"]
        assert abs(sim["E_final"] - pred) < tol

    def test_phase_times_match(self):
        T = 60.0
        sim = simulate(T, CK, PW, T_base=4000.0, n_trials=400, seed=2)
        ph = model.phase_times(T, CK, 4000.0)
        assert sim["T_cal"] == pytest.approx(float(ph.T_cal), rel=0.04)
        assert sim["T_io"] == pytest.approx(float(ph.T_io), rel=0.06)

    def test_no_failures_limit(self):
        ck = CheckpointParams(C=10, R=10, D=1, mu=1e12, omega=0.5)
        r = simulate_once(60.0, ck, PW, 1000.0, np.random.default_rng(0))
        assert r.n_failures == 0
        assert r.wall_time == pytest.approx(
            float(model.time_fault_free(60.0, ck, 1000.0)), rel=2e-3)

    def test_algo_t_beats_neighbors_in_simulation(self):
        """The analytic optimum should (statistically) dominate clearly
        sub-optimal periods in simulated wall time."""
        t_star = t_opt_time(CK)
        wall_star = simulate(t_star, CK, PW, 4000.0, n_trials=300,
                             seed=3)["T_final"]
        for t in (t_star / 3.0, t_star * 3.0):
            wall = simulate(t, CK, PW, 4000.0, n_trials=300, seed=3)["T_final"]
            assert wall_star < wall

    def test_algo_e_saves_energy_in_simulation(self):
        t_t = t_opt_time(CK)
        t_e = t_opt_energy(CK, PW)
        st = simulate(t_t, CK, PW, 4000.0, n_trials=400, seed=4)
        se = simulate(t_e, CK, PW, 4000.0, n_trials=400, seed=4)
        assert se["E_final"] < st["E_final"]          # AlgoE saves energy...
        assert se["T_final"] > st["T_final"]          # ...and costs time.

    def test_rollback_semantics(self):
        """Work is never lost beyond one period + checkpoint overlap."""
        rng = np.random.default_rng(5)
        r = simulate_once(60.0, CK, PW, 2000.0, rng)
        # executed work >= useful work; overhead bounded by failures * (T + C)
        assert r.work_executed >= 2000.0 - 1e-9
        assert r.work_executed <= 2000.0 + r.n_failures * (60.0 + 10.0) + 60.0


class TestCheckpointPolicy:
    def test_policy_converges_to_measured_params(self):
        pol = CheckpointPolicy(PolicyConfig(strategy="algo_t", C_s=600.0,
                                            mu_s=7200.0), PW)
        for _ in range(50):
            pol.observe_checkpoint(duration_s=60.0,
                                   slowdown_work_fraction=0.5)
        ck = pol.checkpoint_params()
        assert ck.C == pytest.approx(60.0, rel=1e-6)
        assert ck.omega == pytest.approx(0.5, rel=1e-6)

    def test_policy_period_matches_formula(self):
        pol = CheckpointPolicy(PolicyConfig(strategy="algo_t", C_s=10.0,
                                            R_s=10.0, D_s=1.0, mu_s=300.0,
                                            omega=0.5), PW)
        assert pol.period_seconds() == pytest.approx(t_opt_time(CK), rel=1e-9)

    def test_policy_period_steps(self):
        pol = CheckpointPolicy(PolicyConfig(strategy="fixed",
                                            fixed_period_s=100.0), PW)
        for _ in range(20):
            pol.observe_step_time(2.0)
        assert pol.period_steps() == 50

    def test_mu_estimation_from_failure_log(self):
        pol = CheckpointPolicy(PolicyConfig(strategy="algo_t", mu_s=1000.0),
                               PW)
        t = 0.0
        rng = np.random.default_rng(0)
        pol.observe_failure(t)
        for _ in range(200):
            t += rng.exponential(500.0)
            pol.observe_failure(t)
        assert pol.mu_estimate_s == pytest.approx(500.0, rel=0.2)

    def test_energy_strategy_longer_period(self):
        cfgT = PolicyConfig(strategy="algo_t", C_s=10, R_s=10, D_s=1,
                            mu_s=300, omega=0.5)
        cfgE = PolicyConfig(strategy="algo_e", C_s=10, R_s=10, D_s=1,
                            mu_s=300, omega=0.5)
        pT = CheckpointPolicy(cfgT, PW)
        pE = CheckpointPolicy(cfgE, PW)
        assert pE.period_seconds() > pT.period_seconds()

    def test_report_contains_predictions(self):
        pol = CheckpointPolicy(PolicyConfig(strategy="algo_e", C_s=10, R_s=10,
                                            D_s=1, mu_s=300, omega=0.5), PW)
        rep = pol.report()
        assert rep["predicted_energy_ratio"] > 1.0
        assert rep["predicted_time_ratio"] > 1.0

    def test_drift_triggers_resolve(self):
        pol = CheckpointPolicy(PolicyConfig(strategy="algo_t", C_s=10, R_s=10,
                                            D_s=1, mu_s=300, omega=0.5), PW)
        p0 = pol.period_seconds()
        # 4x larger C (well past drift threshold) must change the decision.
        for _ in range(50):
            pol.observe_checkpoint(duration_s=40.0)
        p1 = pol.period_seconds()
        assert p1 > p0 * 1.5
