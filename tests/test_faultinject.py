"""IO fault-injection tier (marker: ``faultinject``).

Exercises the failure-interruptible async checkpoint stack end to end:

* :class:`FaultPlan` semantics (stall / torn / corrupt / transient / hard
  error, trigger budgets, named fault points);
* store-level consequences — torn generations stay invisible and get
  garbage-collected, corrupted generations fail CRC validation and
  ``latest()`` falls back, aborted writes raise :class:`FlushAborted`;
* the :class:`FlushController` retry/backoff/abort machinery;
* the parametrized FAULT-POINT SWEEP: a fault scripted at every point of
  the write pipeline (snapshot, mid-shard-write, between shard rename
  and manifest commit, during the buddy push, during retry backoff)
  while failures are injected — the restored state must always be a
  valid committed generation and the run must end bit-identical to the
  no-fault baseline (rollback identity);
* graceful degradation: a persistently failing PFS flips the manager to
  buddy-only (alarm + policy re-solve at the degraded tier) until the
  store heals, and the run still completes bit-identically.

CI runs this file on its own via ``pytest -m faultinject``; it also runs
in the default suite.
"""
import threading

import jax
import numpy as np
import pytest

from repro.ckpt import (CheckpointManager, FaultPlan, FlushAborted,
                        FlushController, ManagerConfig, ShardedStore,
                        StoreConfig, TransientIOError)
from repro.configs import get_config, reduced
from repro.core.policy import CheckpointPolicy, PolicyConfig
from repro.data import for_arch
from repro.energy import EnergyMeter, PAPER_EXASCALE_PROFILE
from repro.ft import (FailureInjector, FailureModel, FaultTolerantTrainer,
                      TrainerConfig)
from repro.models import build
from repro.optim import adamw

pytestmark = pytest.mark.faultinject

PW = PAPER_EXASCALE_PROFILE.power_params()


def small_tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (256, 64)),
            "b": jax.numpy.arange(7, dtype=jax.numpy.int32)}


# ---------------------------------------------------------------------------
# FaultPlan semantics
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_rejects_unknown_point_and_kind(self):
        with pytest.raises(ValueError):
            FaultPlan(fail_at="nonsense")
        with pytest.raises(ValueError):
            FaultPlan(kind="nonsense")

    def test_wrong_point_is_noop(self):
        plan = FaultPlan(fail_at="manifest_commit", kind="error")
        assert plan.take("shard_write") is None
        assert plan.fired == 0

    def test_error_honors_trigger_budget(self):
        plan = FaultPlan(fail_at="shard_write", kind="error", max_triggers=2)
        for _ in range(2):
            with pytest.raises(IOError):
                plan.take("shard_write")
        assert plan.take("shard_write") is None       # budget spent
        assert plan.fired == 2

    def test_transient_burst_then_clean(self):
        plan = FaultPlan(fail_at="shard_write", kind="transient",
                         transient_errors=3)
        for _ in range(3):
            with pytest.raises(TransientIOError):
                plan.take("shard_write")
        assert plan.take("shard_write") is None

    def test_stall_interruptible_by_abort(self):
        plan = FaultPlan(fail_at="shard_write", kind="stall", stall_s=30.0)
        abort = threading.Event()
        abort.set()
        with pytest.raises(FlushAborted):
            plan.take("shard_write", abort=abort)


# ---------------------------------------------------------------------------
# Store under injection
# ---------------------------------------------------------------------------

class TestStoreInjection:
    def test_torn_write_leaves_uncommitted_generation(self, tmp_path):
        store = ShardedStore(StoreConfig(root=str(tmp_path)))
        tree = small_tree()
        store.save(1, tree)
        store.fault_plan = FaultPlan(fail_at="shard_write", kind="torn",
                                     torn_after_bytes=128)
        with pytest.raises(IOError):
            store.save(2, tree)
        # the torn generation has no manifest -> invisible to latest()
        out, step = store.restore(tree)
        assert step == 1
        torn = store.root / "step_000000002"
        assert torn.exists() and not (torn / "manifest.json").exists()
        # the next committed save garbage-collects the torn leftover
        store.fault_plan = None
        store.save(3, tree)
        assert not torn.exists()

    def test_gc_keeps_newer_uncommitted_generation(self, tmp_path):
        """An uncommitted generation NEWER than the newest committed one
        may be a flush in flight — _gc must not reclaim it."""
        store = ShardedStore(StoreConfig(root=str(tmp_path)))
        tree = small_tree()
        store.save(1, tree)
        inflight = store.root / "step_000000009"
        inflight.mkdir()
        (inflight / "shard_00000.npz.tmp").write_bytes(b"partial")
        store.save(2, tree)                    # triggers _gc
        assert inflight.exists()

    def test_corruption_commits_but_fails_validation(self, tmp_path):
        store = ShardedStore(StoreConfig(root=str(tmp_path)))
        tree = small_tree()
        store.save(1, tree)
        store.fault_plan = FaultPlan(fail_at="manifest_commit",
                                     kind="corrupt")
        store.save(2, tree)                    # commits, then flips a byte
        gen2 = store.root / "step_000000002"
        assert (gen2 / "manifest.json").exists()
        assert not store.validate(gen2)
        out, step = store.restore(tree)
        assert step == 1                       # fell back across it

    def test_abort_event_interrupts_save(self, tmp_path):
        store = ShardedStore(StoreConfig(root=str(tmp_path)))
        abort = threading.Event()
        abort.set()
        with pytest.raises(FlushAborted):
            store.save(5, small_tree(), abort=abort)
        assert store.latest() is None
        assert store.invalidate(5)             # torn leftover reclaimed
        assert store.generations() == []

    def test_invalidate_missing_generation(self, tmp_path):
        store = ShardedStore(StoreConfig(root=str(tmp_path)))
        assert not store.invalidate(42)


# ---------------------------------------------------------------------------
# FlushController
# ---------------------------------------------------------------------------

def _controller_rig(tmp_path, **cfg):
    store = ShardedStore(StoreConfig(root=str(tmp_path)))
    ctl = FlushController(store, **cfg)
    outcomes = []
    return store, ctl, outcomes, (
        lambda step, outcome, payload: outcomes.append(outcome))


class TestFlushController:
    def test_transient_errors_absorbed_by_retry(self, tmp_path):
        store, ctl, outcomes, done = _controller_rig(tmp_path, retries=2,
                                                     backoff_s=0.001)
        store.fault_plan = FaultPlan(fail_at="shard_write",
                                     kind="transient", transient_errors=2)
        tree = small_tree()
        ctl.run_sync(1, lambda abort: store.save(1, tree, abort=abort),
                     done)
        assert outcomes == ["ok"]
        assert store.validate(store.latest())

    def test_retry_budget_exhausted_fails(self, tmp_path):
        store, ctl, outcomes, done = _controller_rig(tmp_path, retries=1,
                                                     backoff_s=0.001)
        store.fault_plan = FaultPlan(fail_at="shard_write",
                                     kind="transient", transient_errors=5)
        tree = small_tree()
        ctl.run_sync(1, lambda abort: store.save(1, tree, abort=abort),
                     done)
        assert outcomes == ["failed"]
        assert store.latest() is None

    def test_abort_interrupts_backoff(self, tmp_path):
        store, ctl, outcomes, done = _controller_rig(tmp_path, retries=3,
                                                     backoff_s=60.0)
        store.fault_plan = FaultPlan(fail_at="shard_write",
                                     kind="transient", transient_errors=5)
        tree = small_tree()
        ctl.submit(1, lambda abort: store.save(1, tree, abort=abort), done)
        assert ctl.abort()                     # interrupt the 60 s backoff
        assert outcomes == ["aborted"]

    def test_injected_fault_during_retry_backoff(self, tmp_path):
        store, ctl, outcomes, done = _controller_rig(tmp_path, retries=3,
                                                     backoff_s=0.001)
        store.fault_plan = FaultPlan(fail_at="retry_backoff", kind="error")
        tree = small_tree()

        def write(abort):
            raise TransientIOError("first attempt fails")
        ctl.run_sync(1, write, done)
        assert outcomes == ["failed"]


# ---------------------------------------------------------------------------
# Manager: discard_in_flight + degraded mode (unit level)
# ---------------------------------------------------------------------------

def _policy(strategy="fixed", period=10.0, **kw):
    return CheckpointPolicy(PolicyConfig(strategy=strategy,
                                         fixed_period_s=period, **kw), PW)


class TestManagerFaults:
    def test_discard_in_flight_rejects_raced_commit(self, tmp_path):
        """Even a flush that won the real-time race to commit must be
        rejected when the virtual clock says it was interrupted."""
        mgr = CheckpointManager(ShardedStore(StoreConfig(str(tmp_path))),
                                _policy(),
                                ManagerConfig(async_write=False))
        t1, t2 = small_tree(1), small_tree(2)
        mgr.checkpoint(1, t1)
        mgr.checkpoint(2, t2)                  # committed in real time
        mgr.discard_in_flight(2, level=2)      # ... but virtually lost
        out, step, source = mgr.restore(t1)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(t1["a"]))

    def test_buddy_revert_falls_back_one_generation(self, tmp_path):
        mgr = CheckpointManager(ShardedStore(StoreConfig(str(tmp_path))),
                                _policy(),
                                ManagerConfig(async_write=False,
                                              pfs_every=2))
        t1, t2 = small_tree(1), small_tree(2)
        mgr.checkpoint(1, t1)                  # deep (+ buddy)
        mgr.checkpoint(2, t2)                  # buddy-only
        mgr.discard_in_flight(2, level=1)      # failure in buddy window
        out, step, source = mgr.restore(t1)
        assert (step, source) == (1, "store")  # tie prefers the store

    def test_degrades_after_consecutive_failures_then_heals(self, tmp_path):
        store = ShardedStore(StoreConfig(str(tmp_path)))
        alarms = []
        # period 0 -> due() every step (min_period_steps clamps to 1)
        mgr = CheckpointManager(
            store, _policy(period=0.0),
            ManagerConfig(async_write=False, pfs_every=1,
                          flush_retries=0, degrade_after=2, heal_every=2),
            on_alarm=alarms.append)
        tree = small_tree()
        store.fault_plan = FaultPlan(fail_at="shard_write", kind="error",
                                     max_triggers=2)
        assert mgr.checkpoint(1, tree) == 2    # fails (1/2)
        assert mgr.checkpoint(2, tree) == 2    # fails (2/2) -> degraded
        assert mgr.degraded
        assert [a["kind"] for a in alarms] == ["pfs_degraded"]
        assert not mgr.policy.deep_available
        # degraded: scheduled deep writes downgrade to buddy-only...
        assert mgr.due(3) == 1
        assert mgr.checkpoint(3, tree) == 1
        # ... except the heal probe, which succeeds (budget exhausted)
        assert mgr.due(4) == 2
        assert mgr.checkpoint(4, tree) == 2
        assert not mgr.degraded
        assert [a["kind"] for a in alarms] == ["pfs_degraded", "pfs_healed"]
        assert mgr.policy.deep_available
        assert store.validate(store.latest())

    def test_aborts_do_not_count_toward_degradation(self, tmp_path):
        mgr = CheckpointManager(
            ShardedStore(StoreConfig(str(tmp_path))), _policy(),
            ManagerConfig(async_write=False, degrade_after=1))
        tree = small_tree()
        for step in (1, 2, 3):
            mgr.checkpoint(step, tree)
            mgr.discard_in_flight(step, level=2)
        assert not mgr.degraded and mgr.alarms == []


class TestPolicyDegradedSolve:
    def test_buddy_only_resolve_and_restore(self):
        from repro.core import optimal
        from repro.energy import PAPER_EXASCALE_ML_PROFILE
        prof = PAPER_EXASCALE_ML_PROFILE
        pol = CheckpointPolicy(
            PolicyConfig(strategy="algo_t_ml", C_s=1.5, R_s=1.5, D_s=0.2,
                         C1_s=0.3, R1_s=0.3, D1_s=0.1, q=0.15, mu_s=15.0,
                         omega=0.0, mu_from_observations=False),
            prof.power_params(), ml_power=prof.ml_power_params())
        T_full, m_full = pol.period_seconds(), pol.deep_every()
        assert m_full >= 1
        pol.set_deep_available(False)
        assert pol.deep_every() == 1
        ck = pol.checkpoint_params_ml().buddy_only()
        assert pol.period_seconds() == pytest.approx(optimal.t_opt_time(ck))
        pol.set_deep_available(True)
        assert (pol.period_seconds(), pol.deep_every()) == (T_full, m_full)

    def test_overlap_for_levels(self):
        pol = CheckpointPolicy(
            PolicyConfig(strategy="algo_t_ml", omega=0.2, omega2=0.9,
                         mu_from_observations=False), PW)
        assert pol.overlap_for(1) == pytest.approx(0.2)
        assert pol.overlap_for(2) == pytest.approx(0.9)
        single = CheckpointPolicy(PolicyConfig(strategy="algo_t",
                                               omega=0.4), PW)
        assert single.overlap_for(2) == pytest.approx(0.4)


# ---------------------------------------------------------------------------
# Fault-point sweep: rollback identity under scripted IO faults
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_rig():
    cfg = reduced(get_config("starcoder2-3b"))
    m = build(cfg)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1)
    step_fn = jax.jit(m.make_train_step(ocfg))
    return cfg, m, ocfg, step_fn


def _trainer(tmp, rig, mu_s, seed=0, steps=16, fault_plan=None,
             manager_kw=None, omega2=None):
    cfg, m, ocfg, step_fn = rig
    params = m.init(jax.random.key(0))
    opt = adamw.init_state(params, ocfg)
    data = for_arch(cfg, batch=4, seq_len=64, seed=1)
    pol = CheckpointPolicy(PolicyConfig(strategy="algo_t", C_s=0.05,
                                        R_s=0.05, D_s=0.1, mu_s=mu_s,
                                        omega=0.5, omega2=omega2), PW)
    store = ShardedStore(StoreConfig(root=str(tmp)))
    store.fault_plan = fault_plan
    mgr = CheckpointManager(store, pol,
                            ManagerConfig(pfs_every=2, flush_backoff_s=0.001,
                                          **(manager_kw or {})))
    meter = EnergyMeter(PAPER_EXASCALE_PROFILE)
    inj = FailureInjector(FailureModel(mu_s=mu_s, downtime_s=0.1, seed=seed))
    return FaultTolerantTrainer(
        train_step=step_fn, state=(params, opt), data=data, policy=pol,
        manager=mgr, meter=meter, failures=inj,
        config=TrainerConfig(total_steps=steps, sim_seconds_per_step=1.0))


class _Chain:
    """Several FaultPlans consulted in sequence (duck-typed for
    ``store.fault_plan``) — lets a scripted fault reach points that only
    exist downstream of another failure (``retry_backoff``)."""

    def __init__(self, *plans):
        self.plans = plans

    @property
    def fired(self):
        return sum(p.fired for p in self.plans)

    def take(self, point, abort=None):
        out = None
        for p in self.plans:
            r = p.take(point, abort=abort)
            out = out if r is None else r
        return out


def _plan_for(point, kind):
    if point == "retry_backoff":
        # the backoff point only exists after a failed write attempt:
        # chain one transient shard-write failure in front of it.
        return _Chain(
            FaultPlan(fail_at="shard_write", kind="transient",
                      transient_errors=1),
            FaultPlan(fail_at=point, kind=kind, max_triggers=2))
    return FaultPlan(fail_at=point, kind=kind, max_triggers=2,
                     transient_errors=2, stall_s=0.005,
                     torn_after_bytes=512)


SWEEP_POINTS = [
    ("snapshot", "stall"),
    ("shard_write", "torn"),
    ("shard_write", "transient"),
    ("shard_rename", "error"),
    ("manifest_commit", "error"),
    ("manifest_commit", "corrupt"),
    ("buddy_push", "error"),
    ("retry_backoff", "error"),
]


class TestFaultPointSweep:
    @pytest.fixture(scope="class")
    def baseline(self, tiny_rig, tmp_path_factory):
        t = _trainer(tmp_path_factory.mktemp("clean"), tiny_rig,
                     mu_s=float("inf"))
        rep = t.run()
        return t, rep

    @pytest.mark.parametrize("point,kind", SWEEP_POINTS,
                             ids=[f"{p}-{k}" for p, k in SWEEP_POINTS])
    def test_rollback_identity_with_fault(self, tiny_rig, tmp_path,
                                          baseline, point, kind):
        """A scripted IO fault at any pipeline point, under injected
        failures, must leave every restore on a valid committed
        generation and end bit-identical to the no-failure baseline."""
        t_clean, rep_c = baseline
        plan = _plan_for(point, kind)
        t = _trainer(tmp_path, tiny_rig, mu_s=5.0, seed=3, fault_plan=plan)
        rep = t.run()
        assert rep["n_failures"] >= 1
        assert plan.fired >= 1                 # the fault actually fired
        assert rep["final_step"] == rep_c["final_step"]
        for a, b in zip(jax.tree.leaves(t_clean.state[0]),
                        jax.tree.leaves(t.state[0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # every surviving committed generation must validate (torn and
        # corrupt generations are invisible or rejected, never restored)
        store = t.manager.store
        for gen in store.generations():
            if (gen / "manifest.json").exists() and kind != "corrupt":
                assert store.validate(gen)
        if store.latest() is not None:
            assert store.validate(store.latest())


class TestDegradedModeEndToEnd:
    def test_degrade_alarm_resolve_heal(self, tiny_rig, tmp_path):
        """Persistently failing PFS: the run must complete buddy-only
        under a degradation alarm, re-solve the policy at the degraded
        tier, then heal once the store recovers — bit-identical to the
        clean baseline throughout."""
        t_clean = _trainer(tmp_path / "clean", tiny_rig, mu_s=float("inf"),
                           steps=24)
        rep_c = t_clean.run()
        plan = FaultPlan(fail_at="shard_write", kind="error",
                         max_triggers=4)
        t = _trainer(tmp_path / "fault", tiny_rig, mu_s=6.0, seed=1,
                     steps=24, fault_plan=plan,
                     manager_kw=dict(flush_retries=0, degrade_after=2,
                                     heal_every=2))
        rep = t.run()
        kinds = [a["kind"] for a in rep["alarms"]]
        assert "pfs_degraded" in kinds
        assert rep["flush_errors"] >= 2
        # the store eventually healed (fault budget exhausted by probes)
        assert "pfs_healed" in kinds
        assert not rep["pfs_degraded"]
        assert t.policy.deep_available
        # degraded stretches wrote buddy-only checkpoints
        assert 1 in {c["level"] for c in rep["checkpoints"]}
        assert rep["final_step"] == rep_c["final_step"]
        for a, b in zip(jax.tree.leaves(t_clean.state[0]),
                        jax.tree.leaves(t.state[0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Shared-mutable-default regressions (the config aliasing bug class)
# ---------------------------------------------------------------------------

class TestPerInstanceConfigs:
    def test_manager_configs_not_shared(self, tmp_path):
        m1 = CheckpointManager(ShardedStore(StoreConfig(str(tmp_path / "a"))),
                               _policy())
        m2 = CheckpointManager(ShardedStore(StoreConfig(str(tmp_path / "b"))),
                               _policy())
        m1.cfg.pfs_every = 7
        assert m2.cfg.pfs_every != 7

    def test_watchdog_configs_not_shared(self):
        from repro.ft import StepTimeWatchdog
        w1, w2 = StepTimeWatchdog(), StepTimeWatchdog()
        w1.cfg.sigma_threshold = 99.0
        assert w2.cfg.sigma_threshold != 99.0

    def test_trainer_configs_not_shared(self, tiny_rig, tmp_path):
        t1 = _trainer(tmp_path / "a", tiny_rig, mu_s=float("inf"))
        t2 = _trainer(tmp_path / "b", tiny_rig, mu_s=float("inf"))
        t1.cfg.total_steps = 999
        assert t2.cfg.total_steps != 999
