"""Checkpoint runtime + fault-tolerance tests: atomicity, corruption
fallback, buddy recovery, compression, bit-exact resume, elasticity,
watchdog, energy accounting."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (ShardedStore, StoreConfig, CheckpointManager,
                        ManagerConfig)
from repro.configs import get_config, reduced
from repro.core.failures import get_process
from repro.core.policy import CheckpointPolicy, PolicyConfig
from repro.data import for_arch
from repro.energy import EnergyMeter, Phase, PAPER_EXASCALE_PROFILE
from repro.ft import (FailureInjector, FailureModel, FaultTolerantTrainer,
                      TrainerConfig, StepTimeWatchdog, plan_reshard)
from repro.models import build
from repro.optim import adamw

PW = PAPER_EXASCALE_PROFILE.power_params()


def small_tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (128, 64)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jax.random.normal(k, (4096, 32))}}


# ---------------------------------------------------------------------------
# Store
# ---------------------------------------------------------------------------

class TestStore:
    def test_roundtrip(self, tmp_path):
        store = ShardedStore(StoreConfig(root=str(tmp_path)))
        tree = small_tree()
        store.save(5, tree)
        out, step = store.restore(tree)
        assert step == 5
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_retention_gc(self, tmp_path):
        store = ShardedStore(StoreConfig(root=str(tmp_path), retain=2))
        tree = small_tree()
        for s in (1, 2, 3, 4):
            store.save(s, tree)
        gens = [g.name for g in store.generations()]
        assert gens == ["step_000000003", "step_000000004"]

    def test_corruption_falls_back_one_generation(self, tmp_path):
        store = ShardedStore(StoreConfig(root=str(tmp_path)))
        t1 = small_tree(1)
        t2 = small_tree(2)
        store.save(1, t1)
        store.save(2, t2)
        # corrupt the newest shard
        newest = store.generations()[-1]
        shard = next(newest.glob("shard_*.npz"))
        data = bytearray(shard.read_bytes())
        data[100] ^= 0xFF
        shard.write_bytes(bytes(data))
        out, step = store.restore(t1)
        assert step == 1          # fell back
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(t1["a"]))

    def test_torn_write_no_manifest_is_invisible(self, tmp_path):
        store = ShardedStore(StoreConfig(root=str(tmp_path)))
        tree = small_tree()
        store.save(1, tree)
        # simulate a torn write: shard present, manifest missing
        torn = tmp_path / "step_000000009"
        torn.mkdir()
        (torn / "shard_00000.npz").write_bytes(b"garbage")
        out, step = store.restore(tree)
        assert step == 1

    def test_compressed_checkpoint_smaller_and_close(self, tmp_path):
        plain = ShardedStore(StoreConfig(root=str(tmp_path / "p")))
        comp = ShardedStore(StoreConfig(root=str(tmp_path / "c"),
                                        compress=True))
        tree = {"w": jax.random.normal(jax.random.key(0), (512, 512))}
        m1 = plain.save(1, tree)
        m2 = comp.save(1, tree)
        assert m2["bytes"] < 0.4 * m1["bytes"]
        out, _ = comp.restore(tree)
        rel = float(jnp.max(jnp.abs(out["w"] - tree["w"]))
                    / jnp.max(jnp.abs(tree["w"])))
        assert rel < 0.01

    def test_restore_empty_store(self, tmp_path):
        store = ShardedStore(StoreConfig(root=str(tmp_path)))
        out, step = store.restore(small_tree())
        assert out is None and step is None


# ---------------------------------------------------------------------------
# Manager (async, buddy, policy-driven cadence)
# ---------------------------------------------------------------------------

def _policy(strategy="fixed", period=10.0, **kw):
    return CheckpointPolicy(PolicyConfig(strategy=strategy,
                                         fixed_period_s=period, **kw), PW)


class TestManager:
    def test_async_checkpoint_and_restore(self, tmp_path):
        pol = _policy()
        mgr = CheckpointManager(ShardedStore(StoreConfig(str(tmp_path))),
                                pol)
        tree = small_tree()
        mgr.checkpoint(3, tree)
        mgr.wait()
        out, step, source = mgr.restore(tree)
        assert step == 3 and source == "store"

    def test_buddy_recovery_when_store_lost(self, tmp_path):
        pol = _policy()
        mgr = CheckpointManager(ShardedStore(StoreConfig(str(tmp_path))),
                                pol)
        tree = small_tree()
        mgr.checkpoint(7, tree, block=True)
        # catastrophic store loss
        for g in mgr.store.generations():
            for p in sorted(g.glob("**/*"), reverse=True):
                p.unlink()
            g.rmdir()
        out, step, source = mgr.restore(tree)
        assert step == 7 and source == "buddy"
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))

    def test_policy_cadence(self, tmp_path):
        pol = _policy(period=5.0)
        for _ in range(5):
            pol.observe_step_time(1.0)     # 1 s/step -> every 5 steps
        mgr = CheckpointManager(ShardedStore(StoreConfig(str(tmp_path))),
                                pol)
        tree = small_tree()
        saved = [step for step in range(1, 21)
                 if mgr.maybe_checkpoint(step, tree)]
        mgr.wait()
        assert saved == [1, 6, 11, 16]

    def test_measured_C_feeds_policy(self, tmp_path):
        pol = _policy(strategy="algo_t", C_s=99.0, mu_s=3600.0)
        mgr = CheckpointManager(ShardedStore(StoreConfig(str(tmp_path))),
                                pol)
        mgr.checkpoint(1, small_tree(), block=True)
        assert pol.checkpoint_params().C < 10.0   # measured, not the prior


# ---------------------------------------------------------------------------
# Manager multilevel paths (buddy every checkpoint, PFS every m-th)
# ---------------------------------------------------------------------------

class TestManagerMultilevel:
    def test_maybe_checkpoint_honors_pfs_every_m(self, tmp_path):
        """Every period ends in a buddy push; only every m-th goes deep."""
        pol = _policy(period=1.0)
        for _ in range(3):
            pol.observe_step_time(1.0)       # 1 s/step -> every step
        mgr = CheckpointManager(
            ShardedStore(StoreConfig(str(tmp_path))), pol,
            ManagerConfig(async_write=False, pfs_every=3))
        tree = small_tree()
        saved = [s for s in range(1, 10) if mgr.maybe_checkpoint(s, tree)]
        assert saved == list(range(1, 10))
        # deep writes at checkpoint ordinals 0, 3, 6 -> steps 1, 4, 7
        # (retention keeps the newest two PFS generations)
        gens = [g.name for g in mgr.store.generations()]
        assert gens == ["step_000000004", "step_000000007"]
        assert [s["level"] for s in mgr.stats] == [2, 1, 1] * 3
        # the buddy holds the freshest state -> newest-wins restore
        out, step, source = mgr.restore(tree)
        assert source == "buddy" and step == 9

    def test_buddy_restore_after_torn_pfs_write(self, tmp_path):
        """A torn deep write must not lose the fresher buddy state."""
        mgr = CheckpointManager(
            ShardedStore(StoreConfig(str(tmp_path))), _policy(),
            ManagerConfig(async_write=False, pfs_every=2))
        t1, t2 = small_tree(1), small_tree(2)
        mgr.checkpoint(1, t1)            # ordinal 0 -> deep (PFS + buddy)
        mgr.checkpoint(2, t2)            # ordinal 1 -> buddy only
        # tear the only PFS generation: shard corrupted post-commit
        gen = mgr.store.generations()[-1]
        shard = next(gen.glob("shard_*.npz"))
        data = bytearray(shard.read_bytes())
        data[50] ^= 0xFF
        shard.write_bytes(bytes(data))
        out, step, source = mgr.restore(t1)
        assert source == "buddy" and step == 2
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(t2["a"]))

    def test_compressed_roundtrip_through_recovery(self, tmp_path):
        """compress=True checkpoints survive the full manager recovery path
        (dequantization on restore, values within the int8 block bound)."""
        mgr = CheckpointManager(
            ShardedStore(StoreConfig(str(tmp_path), compress=True)),
            _policy(), ManagerConfig(async_write=False, use_buddy=False))
        tree = {"w": jax.random.normal(jax.random.key(3), (512, 512))}
        mgr.checkpoint(11, tree)
        out, step, source = mgr.restore(tree)
        assert step == 11 and source == "store"
        rel = float(jnp.max(jnp.abs(out["w"] - tree["w"]))
                    / jnp.max(jnp.abs(tree["w"])))
        assert rel < 0.01

    def test_pfs_every_without_buddy_rejected(self, tmp_path):
        with pytest.raises(ValueError):
            CheckpointManager(
                ShardedStore(StoreConfig(str(tmp_path))), _policy(),
                ManagerConfig(use_buddy=False, pfs_every=2))

    def test_shallow_override_without_buddy_rejected(self, tmp_path):
        """deep=False with no buddy would persist nothing yet still count
        as a taken checkpoint — same invariant as the config guard."""
        mgr = CheckpointManager(
            ShardedStore(StoreConfig(str(tmp_path))), _policy(),
            ManagerConfig(async_write=False, use_buddy=False))
        with pytest.raises(ValueError):
            mgr.checkpoint(1, small_tree(), deep=False)
        assert mgr.stats == [] and mgr._last_ckpt_step is None


# ---------------------------------------------------------------------------
# Energy meter
# ---------------------------------------------------------------------------

class TestEnergyMeter:
    def test_phase_integration(self):
        m = EnergyMeter(PAPER_EXASCALE_PROFILE)
        m.add(Phase.COMPUTE, 10.0)
        m.add(Phase.CHECKPOINT_IO, 2.0)
        m.add(Phase.CHECKPOINT_IO, 1.0, advances_wall=False)  # overlapped
        m.add(Phase.DOWN, 1.0)
        e = m.energy_j()
        assert e["static"] == pytest.approx(13.0 * 10.0)
        assert e["compute"] == pytest.approx(10.0 * 10.0)
        assert e["io"] == pytest.approx(3.0 * 100.0)
        assert m.report()["rho"] == pytest.approx(5.5)

    def test_negative_interval_raises(self):
        m = EnergyMeter(PAPER_EXASCALE_PROFILE)
        with pytest.raises(ValueError):
            m.add(Phase.COMPUTE, -1.0)


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------

class TestWatchdog:
    def test_flags_stragglers_and_escalates(self):
        w = StepTimeWatchdog()
        for i in range(20):
            assert not w.observe(i, 1.0 + 0.001 * (i % 3))
        assert w.observe(20, 5.0)
        assert w.observe(21, 5.0)
        assert w.observe(22, 5.0)
        assert w.events[-1]["escalate"]
        # baseline was not poisoned by the stragglers
        assert w.mean < 1.1

    def test_quiet_run_no_events(self):
        w = StepTimeWatchdog()
        rng = np.random.default_rng(0)
        for i in range(200):
            w.observe(i, 1.0 + 0.01 * rng.standard_normal())
        assert w.events == []

    def test_warmup_spike_absorbed_not_flagged(self):
        """Before min_samples the statistics are too green to trust: the
        spike is not flagged and it updates the baseline."""
        from repro.ft import WatchdogConfig
        w = StepTimeWatchdog(WatchdogConfig(min_samples=8))
        for i in range(3):
            w.observe(i, 1.0)
        assert not w.observe(3, 5.0)
        assert w.events == []
        assert w.mean > 1.0

    def test_escalation_resets_after_normal_step(self):
        from repro.ft import WatchdogConfig
        w = StepTimeWatchdog(WatchdogConfig(consecutive_to_escalate=3))
        for i in range(10):
            w.observe(i, 1.0)
        w.observe(10, 5.0)
        w.observe(11, 5.0)
        assert not w.events[-1]["escalate"]   # only 2 consecutive
        w.observe(12, 1.0)                    # recovery resets the streak
        w.observe(13, 5.0)
        assert not w.events[-1]["escalate"]

    def test_on_straggler_callback(self):
        seen = []
        w = StepTimeWatchdog(on_straggler=seen.append)
        for i in range(10):
            w.observe(i, 1.0)
        w.observe(10, 5.0)
        assert len(seen) == 1
        assert seen[0]["step"] == 10 and seen[0]["duration_s"] == 5.0


# ---------------------------------------------------------------------------
# Elastic plan
# ---------------------------------------------------------------------------

class TestElastic:
    def test_plan_shrinks_data_axis(self):
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(len(jax.devices()))
        plan = plan_reshard(mesh, n_failed_hosts=0, devices_per_host=1)
        assert plan.new_shape == dict(mesh.shape)

    def test_reshard_roundtrip_across_meshes(self, tmp_path):
        """Save under one mesh, restore under a smaller one."""
        store = ShardedStore(StoreConfig(str(tmp_path)))
        tree = small_tree()
        store.save(1, tree)
        out, _ = store.restore(tree)   # single-device 'new mesh'
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Fault-tolerant trainer end-to-end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_rig():
    cfg = reduced(get_config("starcoder2-3b"))
    m = build(cfg)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1)
    step_fn = jax.jit(m.make_train_step(ocfg))
    return cfg, m, ocfg, step_fn


def _trainer(tmp, rig, mu_s, seed=0, steps=20, strategy="algo_t",
             process=None, pfs_every=1, q=0.0):
    cfg, m, ocfg, step_fn = rig
    params = m.init(jax.random.key(0))
    opt = adamw.init_state(params, ocfg)
    data = for_arch(cfg, batch=4, seq_len=64, seed=1)
    pol = CheckpointPolicy(PolicyConfig(strategy=strategy, C_s=0.05,
                                        R_s=0.05, D_s=0.1, mu_s=mu_s,
                                        omega=0.5), PW)
    mgr = CheckpointManager(ShardedStore(StoreConfig(root=str(tmp))), pol,
                            ManagerConfig(pfs_every=pfs_every))
    meter = EnergyMeter(PAPER_EXASCALE_PROFILE)
    inj = FailureInjector(FailureModel(mu_s=mu_s, downtime_s=0.1, seed=seed,
                                       process=process, buddy_loss_prob=q))
    return FaultTolerantTrainer(
        train_step=step_fn, state=(params, opt), data=data, policy=pol,
        manager=mgr, meter=meter, failures=inj,
        config=TrainerConfig(total_steps=steps, sim_seconds_per_step=1.0))


class TestFaultTolerantTrainer:
    def test_watchdog_wired_to_tracker_and_report(self, tmp_path, tiny_rig):
        """The trainer binds the watchdog's callback to its tracker and
        surfaces event counts in the report."""
        from repro.ft import MemoryTracker
        t = _trainer(tmp_path, tiny_rig, mu_s=float("inf"), steps=6)
        t.tracker = MemoryTracker()
        # warm the baseline, then push a straggler burst through the
        # trainer-bound callback (sim step time is constant, so the run
        # itself never flags)
        for i in range(10):
            t.watchdog.observe(i, 1.0)
        for i in range(3):
            t.watchdog.observe(10 + i, 6.0)
        rep = t.run()
        stragglers = t.tracker.of_kind("straggler")
        assert len(stragglers) == 3
        assert stragglers[-1]["escalate"]
        assert rep["straggler_events"] == 3
        assert rep["straggler_escalations"] == 1
        assert t.tracker.of_kind("step")      # step stream flows too

    def test_failures_do_not_change_result(self, tmp_path, tiny_rig):
        """Kill-anywhere property: final params identical with/without
        injected failures."""
        t_clean = _trainer(tmp_path / "clean", tiny_rig, mu_s=float("inf"))
        rep_c = t_clean.run()
        t_fail = _trainer(tmp_path / "fail", tiny_rig, mu_s=7.0, seed=3)
        rep_f = t_fail.run()
        assert rep_f["n_failures"] >= 1
        assert rep_f["final_step"] == rep_c["final_step"]
        for a, b in zip(jax.tree.leaves(t_clean.state[0]),
                        jax.tree.leaves(t_fail.state[0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("process_kw", [
        {"process": get_process("weibull", shape=0.7), "seed": 5},
        {"process": get_process("trace",
                                gaps=[5.0, 9.0, 4.0, 12.0, 6.0],
                                rescale=False), "seed": 0},
    ], ids=["weibull", "trace_replay"])
    def test_rollback_identity_any_process(self, tmp_path, tiny_rig,
                                           process_kw):
        """The kill-anywhere property must hold for every injector: the
        renewal-clock schedules (Weibull, trace replay) roll back through
        the same restore path as the legacy exponential."""
        t_clean = _trainer(tmp_path / "clean", tiny_rig, mu_s=float("inf"))
        rep_c = t_clean.run()
        t_fail = _trainer(tmp_path / "fail", tiny_rig, mu_s=7.0,
                          **process_kw)
        rep_f = t_fail.run()
        assert rep_f["n_failures"] >= 1
        assert rep_f["final_step"] == rep_c["final_step"]
        for a, b in zip(jax.tree.leaves(t_clean.state[0]),
                        jax.tree.leaves(t_fail.state[0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_rollback_identity_multilevel(self, tmp_path, tiny_rig):
        """Kill-anywhere through the two-level manager: buddy-only
        checkpoints every period, PFS every 3rd, and hard failures
        (q=0.5) that drop the buddy and recover from the deep level."""
        t_clean = _trainer(tmp_path / "clean", tiny_rig, mu_s=float("inf"),
                           pfs_every=3)
        rep_c = t_clean.run()
        t_fail = _trainer(tmp_path / "fail", tiny_rig, mu_s=5.0, seed=2,
                          pfs_every=3, q=0.5)
        rep_f = t_fail.run()
        assert rep_f["n_failures"] >= 2
        # both checkpoint levels were exercised
        assert {c["level"] for c in t_fail.manager.stats} == {1, 2}
        assert rep_f["final_step"] == rep_c["final_step"]
        for a, b in zip(jax.tree.leaves(t_clean.state[0]),
                        jax.tree.leaves(t_fail.state[0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_hard_failure_recovers_from_store(self, tmp_path, tiny_rig):
        """q=1: every failure drops the buddy; recovery must come from the
        deep level and the run must still finish bit-identical."""
        t_clean = _trainer(tmp_path / "clean", tiny_rig, mu_s=float("inf"))
        rep_c = t_clean.run()
        t_fail = _trainer(tmp_path / "fail", tiny_rig, mu_s=8.0, seed=2,
                          q=1.0)
        rep_f = t_fail.run()
        assert rep_f["final_step"] == rep_c["final_step"]
        assert rep_f["n_failures"] >= 1
        assert rep_f["n_hard_failures"] == rep_f["n_failures"]
        sources = [e["source"] for e in t_fail.log
                   if e.get("event") == "rollback"]
        assert sources and all(s == "store" for s in sources)
        for a, b in zip(jax.tree.leaves(t_clean.state[0]),
                        jax.tree.leaves(t_fail.state[0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_loss_decreases(self, tmp_path, tiny_rig):
        t = _trainer(tmp_path, tiny_rig, mu_s=float("inf"), steps=10)
        rep = t.run()
        assert rep["losses"][-1] < rep["losses"][0]

    def test_failures_cost_time(self, tmp_path, tiny_rig):
        t_clean = _trainer(tmp_path / "c", tiny_rig, mu_s=float("inf"))
        t_fail = _trainer(tmp_path / "f", tiny_rig, mu_s=6.0, seed=1)
        rc, rf = t_clean.run(), t_fail.run()
        assert rf["wall_s"] > rc["wall_s"]
        assert rf["energy"]["E_total_j"] > rc["energy"]["E_total_j"]

    def test_energy_report_has_paper_parameters(self, tmp_path, tiny_rig):
        t = _trainer(tmp_path, tiny_rig, mu_s=50.0, steps=10)
        rep = t.run()
        assert rep["energy"]["rho"] == pytest.approx(5.5)
        assert "predicted_energy_ratio" in rep["policy"]

    def test_algo_e_longer_period_than_algo_t(self, tmp_path, tiny_rig):
        tt = _trainer(tmp_path / "t", tiny_rig, mu_s=200.0, strategy="algo_t")
        te = _trainer(tmp_path / "e", tiny_rig, mu_s=200.0, strategy="algo_e")
        assert te.policy.period_seconds() > tt.policy.period_seconds()
