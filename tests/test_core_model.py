"""Tests for the analytical core: formulas, optimizers, paper claims."""
import math

import numpy as np
import pytest

from repro.core import (
    CheckpointParams, PowerParams, EXASCALE_POWER_RHO55, EXASCALE_POWER_RHO7,
    fig12_checkpoint,
    time_final, time_fault_free, time_lost_per_failure, phase_times,
    energy_final, energy_breakdown, K_dE_dT,
    t_opt_time, t_opt_time_numeric, t_opt_energy, t_opt_energy_numeric,
    t_young, t_daly, t_msk_energy, energy_quadratic_coefficients,
    paper_printed_coefficients, period_for, evaluate, sweep_nodes,
)
from repro.core.model import K_dE_dT_autodiff


CK = fig12_checkpoint(300.0)          # C=R=10, D=1, omega=1/2, mu=300
PW = EXASCALE_POWER_RHO55             # P = 10/10/100, rho=5.5


# ---------------------------------------------------------------------------
# §3.1 time model
# ---------------------------------------------------------------------------

class TestTimeModel:
    def test_fault_free_overhead(self):
        # With omega=1 the checkpoint is free: T_ff == T_base.
        ck = CheckpointParams(C=10, R=10, D=1, mu=300, omega=1.0)
        assert float(time_fault_free(50.0, ck, 1000.0)) == pytest.approx(1000.0)
        # With omega=0, a period of T delivers T-C work units.
        ck0 = CheckpointParams(C=10, R=10, D=1, mu=300, omega=0.0)
        assert float(time_fault_free(50.0, ck0, 1000.0)) == pytest.approx(
            1000.0 * 50.0 / 40.0)

    def test_time_lost_per_failure_is_linear_in_T(self):
        # D + R + omega C + T/2  (paper's A/B average collapses to T/2)
        got = float(time_lost_per_failure(60.0, CK))
        assert got == pytest.approx(1 + 10 + 0.5 * 10 + 30.0)

    def test_time_final_no_failures_limit(self):
        # mu -> infinity: T_final -> T_ff.
        ck = CheckpointParams(C=10, R=10, D=1, mu=1e15, omega=0.5)
        assert float(time_final(50.0, ck, 777.0)) == pytest.approx(
            float(time_fault_free(50.0, ck, 777.0)), rel=1e-9)

    def test_t_opt_time_closed_form_equals_eq1(self):
        # Eq. (1): sqrt(2 (1-omega) C (mu - (D+R+omega C)))
        expect = math.sqrt(2 * 0.5 * 10 * (300 - (1 + 10 + 5)))
        assert t_opt_time(CK) == pytest.approx(expect, rel=1e-12)

    def test_t_opt_time_matches_numeric_argmin(self):
        for mu in (30.0, 60.0, 120.0, 300.0):
            for omega in (0.0, 0.3, 0.9):
                ck = CheckpointParams(C=10, R=10, D=1, mu=mu, omega=omega)
                assert t_opt_time(ck) == pytest.approx(
                    t_opt_time_numeric(ck), rel=1e-5)

    def test_t_opt_is_interior_minimum(self):
        t = t_opt_time(CK)
        f = lambda x: float(time_final(x, CK))
        assert f(t) < f(t * 0.9) and f(t) < f(t * 1.1)

    def test_omega_one_degenerates_gracefully(self):
        # Fully-overlapped checkpoints: a=0, closed form -> 0; numeric fallback
        # must return a usable period (model still penalizes failures ~T/2).
        ck = CheckpointParams(C=10, R=10, D=1, mu=300, omega=1.0)
        t = t_opt_time(ck)
        lo, hi = ck.valid_period_range()
        assert lo <= t <= hi


# ---------------------------------------------------------------------------
# §3.2 energy model
# ---------------------------------------------------------------------------

class TestEnergyModel:
    def test_phase_identity_blocking(self):
        # omega == 0: no overlap, T_final == T_cal + T_io + T_down.
        ck = CheckpointParams(C=10, R=10, D=1, mu=300, omega=0.0)
        ph = phase_times(60.0, ck, 1000.0)
        assert float(ph.T_final) == pytest.approx(
            float(ph.T_cal + ph.T_io + ph.T_down), rel=1e-12)

    def test_phase_overlap_nonblocking(self):
        # omega > 0: CPU and I/O overlap, sum exceeds wall-clock.
        ph = phase_times(60.0, CK, 1000.0)
        assert float(ph.T_cal + ph.T_io + ph.T_down) > float(ph.T_final)

    def test_energy_breakdown_sums(self):
        bd = energy_breakdown(60.0, CK, PW, 1000.0)
        assert bd["E_final"] == pytest.approx(
            bd["E_cal"] + bd["E_io"] + bd["E_down"] + bd["E_static"])
        assert bd["E_final"] == pytest.approx(
            float(energy_final(60.0, CK, PW, 1000.0)))

    def test_K_dE_dT_is_quadratic(self):
        # The product K * E' interpolated from 3 points predicts a 4th.
        c2, c1, c0 = energy_quadratic_coefficients(CK, PW)
        for t in (40.0, 77.0, 133.0, 200.0):
            q = float(K_dE_dT(t, CK, PW))
            assert q == pytest.approx(c2 * t * t + c1 * t + c0, rel=1e-8)

    def test_analytic_derivative_matches_autodiff(self):
        ts = np.array([35.0, 60.0, 120.0, 240.0])
        np.testing.assert_allclose(
            K_dE_dT(ts, CK, PW), K_dE_dT_autodiff(ts, CK, PW),
            rtol=1e-9)

    def test_paper_printed_coefficients_match(self):
        # DESIGN.md erratum: the FINAL printed display of the paper is correct
        # (the intermediate display is mistyped); verify against the
        # mechanically-derived coefficients to near machine precision.
        ours = energy_quadratic_coefficients(CK, PW)
        paper = paper_printed_coefficients(CK, PW)
        for o, p in zip(ours, paper):
            assert o == pytest.approx(p, rel=1e-9)

    def test_derived_coefficients_match_interpolation_everywhere(self):
        # Our corrected closed form == exact interpolation, for all alpha.
        from repro.core.optimal import derived_coefficients
        for mu in (60.0, 300.0):
            for omega in (0.0, 0.5, 0.9):
                for pw in (PW, EXASCALE_POWER_RHO7):
                    ck = CheckpointParams(C=10, R=10, D=1, mu=mu, omega=omega)
                    ours = energy_quadratic_coefficients(ck, pw)
                    closed = derived_coefficients(ck, pw)
                    for o, p in zip(ours, closed):
                        assert o == pytest.approx(p, rel=1e-9)

    def test_paper_erratum_alpha_neq_1(self):
        # The paper's printed display is wrong when alpha != 1 (rho=7 has
        # alpha=2): documented erratum (DESIGN.md).
        ck = CheckpointParams(C=10, R=10, D=1, mu=60.0, omega=0.0)
        ours = energy_quadratic_coefficients(ck, EXASCALE_POWER_RHO7)
        paper = paper_printed_coefficients(ck, EXASCALE_POWER_RHO7)
        assert ours[0] != pytest.approx(paper[0], rel=1e-3)

    def test_t_opt_energy_root_matches_numeric_argmin(self):
        for mu in (60.0, 120.0, 300.0):
            ck = fig12_checkpoint(mu)
            assert t_opt_energy(ck, PW) == pytest.approx(
                t_opt_energy_numeric(ck, PW), rel=1e-6)

    def test_t_opt_energy_is_interior_minimum(self):
        t = t_opt_energy(CK, PW)
        f = lambda x: float(energy_final(x, CK, PW))
        assert f(t) < f(t * 0.9) and f(t) < f(t * 1.1)

    def test_energy_period_exceeds_time_period_when_io_expensive(self):
        # beta >> alpha: checkpoints cost much energy -> AlgoE stretches T.
        assert t_opt_energy(CK, PW) > t_opt_time(CK)

    def test_equal_powers_collapse_to_time_optimum(self):
        # alpha == beta == gamma -> E proportional-ish to time-like objective;
        # with P_io == P_cal the energy optimum moves close to AlgoT.
        pw = PowerParams(P_static=10.0, P_cal=10.0, P_io=10.0, P_down=10.0)
        te = t_opt_energy(CK, pw)
        tt = t_opt_time(CK)
        assert abs(te - tt) / tt < 0.25


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

class TestBaselines:
    def test_young_daly_values(self):
        assert t_young(CK) == pytest.approx(math.sqrt(2 * 10 * 300) + 10)
        assert t_daly(CK) == pytest.approx(math.sqrt(2 * 10 * 311) + 10)

    def test_daly_geq_young(self):
        assert t_daly(CK) >= t_young(CK)

    def test_young_close_to_algo_t_when_blocking(self):
        # For omega=0 and C,D,R << mu, Eq. (1) ~ Young's formula.
        ck = CheckpointParams(C=1.0, R=1.0, D=0.1, mu=10000.0, omega=0.0)
        assert t_opt_time(ck) == pytest.approx(t_young(ck), rel=0.02)

    def test_msk_energy_period_positive_and_valid(self):
        t = t_msk_energy(CK, PW)
        lo, hi = CK.valid_period_range()
        assert lo < t < hi

    def test_period_for_dispatch(self):
        assert period_for("algo_t", CK) == t_opt_time(CK)
        assert period_for("algo_e", CK, PW) == t_opt_energy(CK, PW)
        assert period_for("young", CK) == t_young(CK)
        assert period_for("daly", CK) == t_daly(CK)
        with pytest.raises(ValueError):
            period_for("nope", CK)


# ---------------------------------------------------------------------------
# Paper §4 experimental claims
# ---------------------------------------------------------------------------

class TestPaperClaims:
    def test_rho_values(self):
        assert EXASCALE_POWER_RHO55.rho == pytest.approx(5.5)
        assert EXASCALE_POWER_RHO7.rho == pytest.approx(7.0)

    def test_claim_20pct_energy_10pct_time_at_mu300(self):
        """'With current values, we can save more than 20% of energy with an
        MTBF of 300 min, at the price of an increase of 10% in the execution
        time' — ratio conventions of Figures 1-2 (ratio - 1)."""
        pt = evaluate(fig12_checkpoint(300.0), EXASCALE_POWER_RHO55)
        assert pt.energy_ratio - 1.0 > 0.20      # 22.5% measured
        assert 0.05 < pt.time_ratio - 1.0 < 0.15  # 10.3% measured

    def test_claim_30pct_peak_between_1e6_and_1e7_nodes(self):
        """Fig. 3: 'up to 30% for a time overhead of only 12%', peak between
        1e6 and 1e7 nodes (rho=7 panel); ratios -> 1 at 1e8."""
        ns = [1e5, 1e6, 3e6, 1e7, 1e8]
        pts = sweep_nodes(ns, EXASCALE_POWER_RHO7)
        e_gain = [p.energy_ratio - 1.0 for p in pts]
        t_loss = [p.time_ratio - 1.0 for p in pts]
        peak = max(e_gain)
        peak_n = ns[e_gain.index(peak)]
        assert 0.25 < peak < 0.35                 # ~29% measured
        assert 1e6 <= peak_n <= 1e7
        assert t_loss[e_gain.index(peak)] < 0.15  # ~12% measured
        # Convergence to 1 at extreme node counts:
        assert e_gain[-1] == pytest.approx(0.0, abs=1e-6)
        assert t_loss[-1] == pytest.approx(0.0, abs=1e-6)

    def test_energy_gain_increases_with_rho(self):
        from repro.core import sweep_rho
        pts = sweep_rho([1.0, 2.0, 5.5, 7.0, 10.0], 300.0)
        gains = [p.energy_saving for p in pts]
        assert all(g2 >= g1 - 1e-12 for g1, g2 in zip(gains, gains[1:]))

    def test_algo_e_never_beats_algo_t_on_time(self):
        for mu in (30.0, 120.0, 300.0):
            pt = evaluate(fig12_checkpoint(mu), EXASCALE_POWER_RHO55)
            assert pt.time_ratio >= 1.0 - 1e-12
            assert pt.energy_ratio >= 1.0 - 1e-12


# ---------------------------------------------------------------------------
# Guard rails
# ---------------------------------------------------------------------------

class TestValidation:
    def test_invalid_params_raise(self):
        with pytest.raises(ValueError):
            CheckpointParams(C=10, R=10, D=1, mu=300, omega=1.5)
        with pytest.raises(ValueError):
            CheckpointParams(C=-1, R=10, D=1, mu=300)
        with pytest.raises(ValueError):
            CheckpointParams(C=1, R=1, D=1, mu=0)
        with pytest.raises(ValueError):
            PowerParams(P_static=0.0, P_cal=1, P_io=1)

    def test_infeasible_platform_raises_in_optimizer(self):
        # mu smaller than the per-failure overhead: no valid period.
        ck = CheckpointParams(C=10, R=10, D=1, mu=12.0, omega=0.0)
        with pytest.raises(ValueError):
            t_opt_time_numeric(ck)

    def test_platform_mtbf_scaling(self):
        ck = CheckpointParams.from_platform(
            n_nodes=1000, mu_ind=1000.0 * 300.0, C=1, R=1, D=0.1)
        assert ck.mu == pytest.approx(300.0)
