"""Sharded, memory-bounded dispatch layer (``repro.sim.dispatch``).

The contract under test: chunk size, shard count, memory budget, and the
persistent compile cache are PURE performance knobs — for a fixed seed
every grid entry point returns bit-identical results no matter how the
work is cut.  Multi-device (sharded) cases run in-process when the suite
itself is launched with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
(the CI multi-device leg) and are skipped cleanly on a single-device
host; one subprocess test covers the sharded path even there.
"""
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.core import fig12_checkpoint, EXASCALE_POWER_RHO55
from repro.core.failures import (Exponential, LogNormal, TraceReplay,
                                 Weibull)
from repro.sim import (DispatchConfig, ParamGrid, evaluate_grid,
                       evaluate_multilevel_grid, evaluate_periods_grid,
                       get_scenario, mu_rho_grid, simulate_candidates,
                       simulate_trajectories, MultilevelParamGrid)
from repro.sim import dispatch as dsp

ROOT = Path(__file__).resolve().parents[1]

CK = fig12_checkpoint(300.0)
PW = EXASCALE_POWER_RHO55

PROCESSES = [Exponential(), Weibull(shape=0.7), LogNormal(sigma=1.0),
             TraceReplay(gaps=(30.0, 90.0, 300.0, 500.0))]

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (run with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=N)")


def _mixed_grid(n=5):
    base = ParamGrid.from_params(CK, PW)
    mus = np.linspace(120.0, 2500.0, n)
    return ParamGrid(**{f: (mus if f == "mu" else np.broadcast_to(v, (n,)))
                        for f, v in base.fields().items()})


def _fields(tb):
    return {k: getattr(tb, k) for k in
            ("wall_time", "energy", "work_executed", "io_time", "down_time",
             "n_failures", "n_checkpoints", "truncated", "gaps_exhausted")}


# ---------------------------------------------------------------------------
# Chunk planning
# ---------------------------------------------------------------------------

class TestChunkPlan:
    def test_single_chunk_when_budget_suffices(self):
        cfg = DispatchConfig(memory_budget_bytes=1 << 30)
        assert dsp.chunk_plan(100, 1, 1024, cfg) == [(0, 100, 100)]

    def test_chunks_are_device_multiples_pow2(self):
        cfg = DispatchConfig(memory_budget_bytes=64 * 1024)
        for ndev in (1, 2, 4):
            plan = dsp.chunk_plan(1000, ndev, 1024, cfg)
            # full chunks share one ndev * 2^k shape
            sizes = {padded for _, _, padded in plan}
            for padded in sizes:
                assert padded % ndev == 0
                q = padded // ndev
                assert q & (q - 1) == 0
            # budget respected by the nominal chunk
            assert max(sizes) * 1024 <= 64 * 1024 or max(sizes) == ndev
            # coverage is exact and ordered
            assert plan[0][0] == 0 and plan[-1][1] == 1000
            for (a, b, _), (c, _d, _e) in zip(plan, plan[1:]):
                assert b == c

    def test_explicit_chunk_override(self):
        plan = dsp.chunk_plan(10, 1, 0, DispatchConfig(chunk=4))
        assert [(s, e) for s, e, _ in plan] == [(0, 4), (4, 8), (8, 10)]

    def test_sharded_whole_grid_pads_to_device_multiple(self):
        (start, stop, padded), = dsp.chunk_plan(
            7, 4, 0, DispatchConfig(memory_budget_bytes=1 << 30))
        assert (start, stop) == (0, 7) and padded == 8


# ---------------------------------------------------------------------------
# Chunked == unchunked bit parity (single device)
# ---------------------------------------------------------------------------

class TestChunkedParity:
    def test_model_grid(self):
        # 150 points: larger than the 64-lane pad quantum, not a multiple
        # of it — chunk boundaries, tail padding, and the budget-driven
        # chunker all really engage.
        grid = mu_rho_grid(list(np.linspace(40, 900, 25)),
                           [2.0, 4.0, 5.5, 6.0, 7.0, 9.0])
        ref = evaluate_grid(grid)
        for cfg in (DispatchConfig(chunk=64),
                    DispatchConfig(chunk=100),
                    DispatchConfig(memory_budget_bytes=1 << 18)):
            out = evaluate_grid(grid, dispatch=cfg)
            for f in ("T_time", "T_energy", "Tf_energy", "E_time",
                      "time_ratio", "energy_ratio", "valid"):
                np.testing.assert_array_equal(
                    getattr(ref, f), getattr(out, f), err_msg=f)

    def test_model_grid_with_degenerate_points(self):
        # mu=20 is degenerate for C=10 (no valid period): the NaN/fallback
        # lanes must survive chunk boundaries and padding untouched.
        grid = mu_rho_grid([20, 60, 300], [5.5])
        ref = evaluate_grid(grid)
        out = evaluate_grid(grid, dispatch=DispatchConfig(chunk=2))
        assert not ref.valid[0, 0] and ref.valid[1, 0]
        np.testing.assert_array_equal(ref.valid, out.valid)
        np.testing.assert_array_equal(ref.T_energy, out.T_energy)

    def test_multilevel_grid(self):
        sc = get_scenario("multilevel_exascale")
        mg = MultilevelParamGrid.from_params(sc.ckpt, sc.power)
        mg = MultilevelParamGrid(**{
            f: (np.linspace(120.0, 900.0, 100) if f == "mu"
                else np.broadcast_to(v, (100,)))
            for f, v in mg.fields().items()})          # > one 64-lane chunk
        ref = evaluate_multilevel_grid(mg, m_values=(1, 2, 4))
        out = evaluate_multilevel_grid(mg, m_values=(1, 2, 4),
                                       dispatch=DispatchConfig(chunk=64))
        for f in ("T_time", "m_time", "T_energy", "m_energy", "E_by_m",
                  "Tf_by_m", "energy_vs_single"):
            np.testing.assert_array_equal(getattr(ref, f), getattr(out, f),
                                          err_msg=f)

    @pytest.mark.parametrize("proc", PROCESSES,
                             ids=lambda p: p.name)
    def test_engine_auto_sampled(self, proc):
        """Grid chunking, trial blocking, and tiny memory budgets leave a
        fixed seed's auto-sampled trajectories bit-identical — for every
        failure process (device samplers with traced parameters)."""
        grid = _mixed_grid()
        kw = dict(T_base=1500.0, n_trials=8, seed=3, process=proc)
        ref = simulate_trajectories(60.0, grid, **kw)
        for cfg in (DispatchConfig(chunk=2),
                    DispatchConfig(chunk=3),
                    DispatchConfig(memory_budget_bytes=1 << 18)):
            out = simulate_trajectories(60.0, grid, dispatch=cfg, **kw)
            for name, a in _fields(ref).items():
                np.testing.assert_array_equal(a, getattr(out, name),
                                              err_msg=name)

    def test_engine_auto_sampled_bulk_device_fallback(self):
        """A process implementing only the PR-4 ``sample_gaps`` device
        hook (no traced sampler) keeps its bulk device draws: results
        must match feeding ``presample_gaps_device`` output explicitly,
        and grid chunking stays a pure knob (whole-grid sampling + per-
        chunk slicing is partition-independent)."""
        from repro.sim import presample_gaps_device

        class BulkOnly(Weibull):
            name = "bulk_only"

            def traced_sampler(self):
                raise NotImplementedError

        grid = _mixed_grid()
        proc = BulkOnly(shape=0.7)
        kw = dict(T_base=1500.0, n_trials=6, seed=9, process=proc)
        ref = simulate_trajectories(60.0, grid, **kw)
        out = simulate_trajectories(60.0, grid,
                                    dispatch=DispatchConfig(chunk=2), **kw)
        np.testing.assert_array_equal(ref.wall_time, out.wall_time)
        # the stream really is the bulk device sampler's (threefry), not
        # the host numpy fallback's (PCG64)
        from repro.sim.engine import fail_capacity_points
        caps = fail_capacity_points(60.0, grid, 1500.0, process=proc)
        gaps = presample_gaps_device(grid, 6, int(caps.max()), seed=9,
                                     process=proc)
        want = simulate_trajectories(60.0, grid, T_base=1500.0, gaps=gaps)
        np.testing.assert_array_equal(ref.wall_time, want.wall_time)

    def test_engine_auto_sampled_host_fallback(self):
        """Processes without a jax sampler chunk via host schedule slices
        — same parity contract."""
        class Odd(Exponential):
            name = "odd"

            def sample_gaps(self, key, size, mean=None):
                raise NotImplementedError

            def traced_sampler(self):
                raise NotImplementedError
        grid = _mixed_grid()
        kw = dict(T_base=1500.0, n_trials=6, seed=1, process=Odd())
        ref = simulate_trajectories(60.0, grid, **kw)
        out = simulate_trajectories(60.0, grid,
                                    dispatch=DispatchConfig(chunk=2), **kw)
        np.testing.assert_array_equal(ref.wall_time, out.wall_time)

    def test_engine_explicit_schedule(self):
        from repro.sim import presample_gaps
        grid = _mixed_grid()
        gaps = presample_gaps(grid, 6, 256, seed=0)
        kw = dict(T_base=1500.0, gaps=gaps)
        ref = simulate_trajectories(60.0, grid, **kw)
        out = simulate_trajectories(
            60.0, grid, dispatch=DispatchConfig(
                chunk=2, memory_budget_bytes=1 << 16), **kw)
        for name, a in _fields(ref).items():
            np.testing.assert_array_equal(a, getattr(out, name),
                                          err_msg=name)

    @pytest.mark.parametrize("kind", ["event", "step"])
    def test_mc_candidates(self, kind):
        grid = _mixed_grid(4)
        Ts = np.array([40.0, 60.0, 90.0])
        kw = dict(T_base=1500.0, n_trials=6, seed=2,
                  process=Weibull(shape=0.7), engine_kind=kind)
        ref = simulate_candidates(Ts, grid, **kw)
        for cfg in (DispatchConfig(chunk=2),
                    # tiny budget: grid chunking AND trial blocking engage
                    # on the auto-sampled candidate path
                    DispatchConfig(memory_budget_bytes=1 << 17)):
            out = simulate_candidates(Ts, grid, dispatch=cfg, **kw)
            np.testing.assert_array_equal(ref.wall_time, out.wall_time)
            np.testing.assert_array_equal(ref.energy, out.energy)

    def test_mc_candidates_single_point_trial_blocking(self):
        """B == 1 (candidate-axis dispatch): a small budget must stream
        the trials axis without changing the sampled results."""
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        Ts = np.linspace(40.0, 90.0, 5)
        kw = dict(T_base=1500.0, n_trials=16, seed=2,
                  process=Weibull(shape=0.7))
        ref = simulate_candidates(Ts, grid, **kw)
        out = simulate_candidates(
            Ts, grid, dispatch=DispatchConfig(memory_budget_bytes=1 << 16),
            **kw)
        np.testing.assert_array_equal(ref.wall_time, out.wall_time)

    def test_mc_periods_grid(self):
        grid = _mixed_grid(3).reshape((3,))
        periods = np.stack([np.full(3, 50.0), np.full(3, 70.0)])
        kw = dict(T_base=1500.0, n_trials=6, seed=5)
        ref = evaluate_periods_grid(grid, Weibull(shape=0.7), periods, **kw)
        out = evaluate_periods_grid(grid, Weibull(shape=0.7), periods,
                                    dispatch=DispatchConfig(chunk=2), **kw)
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k], err_msg=k)

    def test_mc_surrogate_solver(self):
        from repro.core.optimal import MCSurrogate
        kw = dict(T_base=1500.0, n_trials=32, seed=0)
        a = MCSurrogate(CK, PW, Weibull(shape=0.7), **kw).argmin("time")
        b = MCSurrogate(CK, PW, Weibull(shape=0.7),
                        dispatch=DispatchConfig(chunk=4), **kw
                        ).argmin("time")
        assert a == b    # same CRN schedules, same dispatch-invariant sums


# ---------------------------------------------------------------------------
# Sharded == single-device (run under the CI multi-device leg)
# ---------------------------------------------------------------------------

@multi_device
class TestShardedParity:
    def test_model_grid_even_and_uneven(self):
        ndev = jax.device_count()
        for n_mu in (ndev, ndev + 3):      # divisible and padded
            grid = mu_rho_grid(list(np.linspace(60, 600, n_mu)), [5.5])
            ref = evaluate_grid(grid, dispatch=DispatchConfig(shard=False))
            out = evaluate_grid(grid)
            for f in ("T_time", "T_energy", "time_ratio", "energy_ratio"):
                np.testing.assert_array_equal(
                    getattr(ref, f), getattr(out, f), err_msg=f)

    @pytest.mark.parametrize("proc", PROCESSES, ids=lambda p: p.name)
    def test_engine_auto_sampled(self, proc):
        grid = _mixed_grid(jax.device_count() + 1)   # uneven: padding
        kw = dict(T_base=1500.0, n_trials=6, seed=3, process=proc)
        ref = simulate_trajectories(60.0, grid,
                                    dispatch=DispatchConfig(shard=False),
                                    **kw)
        out = simulate_trajectories(60.0, grid, **kw)
        for name, a in _fields(ref).items():
            np.testing.assert_array_equal(a, getattr(out, name),
                                          err_msg=name)

    def test_candidate_axis_sharding_single_point_grid(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        Ts = np.linspace(40.0, 90.0, jax.device_count() + 2)
        kw = dict(T_base=1500.0, n_trials=6, seed=1,
                  process=Weibull(shape=0.7))
        ref = simulate_candidates(Ts, grid,
                                  dispatch=DispatchConfig(shard=False), **kw)
        out = simulate_candidates(Ts, grid, **kw)
        np.testing.assert_array_equal(ref.wall_time, out.wall_time)

    def test_sharding_composes_with_chunking(self):
        grid = mu_rho_grid(list(np.linspace(60, 600, 7)), [2.0, 5.5, 7.0])
        ref = evaluate_grid(grid, dispatch=DispatchConfig(shard=False))
        out = evaluate_grid(
            grid, dispatch=DispatchConfig(chunk=2 * jax.device_count()))
        np.testing.assert_array_equal(ref.T_energy, out.T_energy)


class TestShardedSubprocess:
    """Sharded parity proof that runs even on a single-device host: spawn
    an 8-virtual-device interpreter (device count must be fixed before
    jax initializes) and diff sharded vs shard=False results in there."""

    SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, r"%(src)s")
import numpy as np
import jax
from repro.sim import (DispatchConfig, evaluate_grid, mu_rho_grid,
                       simulate_trajectories, ParamGrid)
from repro.core import fig12_checkpoint, EXASCALE_POWER_RHO55
from repro.core.failures import Weibull

grid = mu_rho_grid(list(np.linspace(60, 600, 11)), [5.5])   # 11: uneven
ref = evaluate_grid(grid, dispatch=DispatchConfig(shard=False))
out = evaluate_grid(grid)
model_eq = bool(np.array_equal(ref.T_energy, out.T_energy, equal_nan=True)
                and np.array_equal(ref.energy_ratio, out.energy_ratio))

base = ParamGrid.from_params(fig12_checkpoint(300.0), EXASCALE_POWER_RHO55)
mus = np.linspace(120.0, 900.0, 11)
g2 = ParamGrid(**{f: (mus if f == "mu" else np.broadcast_to(v, (11,)))
                  for f, v in base.fields().items()})
kw = dict(T_base=1500.0, n_trials=4, seed=3, process=Weibull(shape=0.7))
r2 = simulate_trajectories(60.0, g2, dispatch=DispatchConfig(shard=False),
                           **kw)
o2 = simulate_trajectories(60.0, g2, **kw)
engine_eq = bool(np.array_equal(r2.wall_time, o2.wall_time)
                 and np.array_equal(r2.energy, o2.energy))
print(json.dumps({"n_devices": jax.device_count(),
                  "model_eq": model_eq, "engine_eq": engine_eq}))
"""

    @pytest.fixture(scope="class")
    def results(self):
        out = subprocess.run(
            [sys.executable, "-c", self.SCRIPT % {"src": str(ROOT / "src")}],
            capture_output=True, text=True, timeout=900, cwd=str(ROOT))
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    def test_sharded_matches_single_device_on_eight_devices(self, results):
        assert results["n_devices"] == 8
        assert results["model_eq"] and results["engine_eq"]


# ---------------------------------------------------------------------------
# LRU caches (bounded compiled-callable caches)
# ---------------------------------------------------------------------------

class TestLRUCaches:
    def test_lru_evicts_least_recently_used(self):
        # reprolint: disable=RPL002 (anonymous on purpose: this probes raw eviction order without polluting the global cache_stats() registry)
        lru = dsp.LRUCache(2)
        lru.put("a", 1)
        lru.put("b", 2)
        assert lru.get("a") == 1          # refresh a
        lru.put("c", 3)                   # evicts b
        assert "b" not in lru and "a" in lru and "c" in lru
        assert len(lru) == 2

    def test_device_sampler_eviction_does_not_change_results(self):
        from repro.sim import engine as eng
        from repro.sim import presample_gaps_device
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        ref = np.asarray(presample_gaps_device(grid, 2, 16, seed=7,
                                               process=Weibull(shape=0.7)))
        # Flood the cache past its cap with distinct (process, size) pairs.
        for cap in range(1, eng.DEVICE_SAMPLER_CACHE_SIZE + 4):
            presample_gaps_device(grid, 1, cap, seed=0)
        assert len(eng._DEVICE_SAMPLERS) <= eng.DEVICE_SAMPLER_CACHE_SIZE
        # The (likely evicted) original sampler recompiles to the same
        # stream: eviction is a perf knob, not a semantic one.
        again = np.asarray(presample_gaps_device(grid, 2, 16, seed=7,
                                                 process=Weibull(shape=0.7)))
        np.testing.assert_array_equal(ref, again)

    def test_dispatch_runner_cache_is_bounded(self):
        assert isinstance(dsp._RUNNERS, dsp.LRUCache)
        assert dsp._RUNNERS.maxsize == dsp.RUNNER_CACHE_SIZE


# ---------------------------------------------------------------------------
# Persistent compile cache
# ---------------------------------------------------------------------------

class TestCompileCache:
    def test_cache_helper_writes_and_reuses_entries(self, tmp_path):
        """Two fresh interpreters against one cache dir: the first
        populates it, the second must still produce identical results
        (and the dir must hold serialized executables)."""
        script = (
            "import sys; sys.path.insert(0, r'%s')\n"
            "from repro.sim import enable_compile_cache, evaluate_grid, "
            "mu_rho_grid\n"
            "enable_compile_cache(r'%s')\n"
            "r = evaluate_grid(mu_rho_grid([60, 300], [5.5]))\n"
            "print(float(r.energy_ratio[0, 0]))"
        ) % (ROOT / "src", tmp_path)
        outs = []
        for _ in range(2):
            p = subprocess.run([sys.executable, "-c", script],
                               capture_output=True, text=True, timeout=600)
            assert p.returncode == 0, p.stderr[-2000:]
            outs.append(p.stdout.strip().splitlines()[-1])
        assert outs[0] == outs[1]
        assert any(f.name.endswith("-cache") or "jit_" in f.name
                   for f in tmp_path.iterdir()), list(tmp_path.iterdir())

    def test_env_var_autoenable(self, tmp_path, monkeypatch):
        from repro.sim import cache as c
        monkeypatch.setenv(c.ENV_VAR, str(tmp_path / "cc"))
        assert c.maybe_enable_from_env() == str(tmp_path / "cc")
        monkeypatch.delenv(c.ENV_VAR)
        # restore whatever was active before (idempotent helper)
        if c.active_cache_dir():
            pass

    def test_unusable_cache_dir_warns_instead_of_crashing(self, monkeypatch):
        from repro.sim import cache as c
        monkeypatch.setenv(c.ENV_VAR, "/proc/definitely/not/writable")
        with pytest.warns(RuntimeWarning, match="unusable"):
            assert c.maybe_enable_from_env() is None


class TestEnvKnobGuards:
    def test_malformed_dispatch_env_vars_warn_and_fall_back(self,
                                                            monkeypatch):
        monkeypatch.setenv("REPRO_SWEEP_DEVICES", "all")
        monkeypatch.setenv("REPRO_SWEEP_CHUNK", "64k")
        monkeypatch.setenv("REPRO_SWEEP_MEMORY_MB", "2GB")
        with pytest.warns(RuntimeWarning):
            cfg = dsp.default_config()
        assert cfg.devices is None and cfg.chunk is None
        with pytest.warns(RuntimeWarning):
            assert cfg.budget() == dsp.DEFAULT_MEMORY_BUDGET
        # and the entry points still run
        grid = mu_rho_grid([60, 300], [5.5])
        r = evaluate_grid(grid)
        assert np.isfinite(np.asarray(r.T_energy)).all()
