"""Docs integrity: required files exist, cross-links resolve, and the
link checker actually detects breakage (not just vacuously passing)."""
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs_links as cdl  # noqa: E402


class TestDocsResolve:
    def test_required_docs_exist(self):
        for name in ("README.md", "docs/simulation.md", "docs/serving.md",
                     "docs/training.md"):
            assert (ROOT / name).exists(), name

    def test_all_internal_references_resolve(self):
        errors = []
        for md in cdl.doc_files():
            assert md.exists(), md
            errors.extend(cdl.check_file(md))
        assert not errors, "\n".join(errors)

    def test_docs_are_cross_linked(self):
        """The three subsystem guides must reference each other and the
        README must index all of them."""
        readme = (ROOT / "README.md").read_text()
        for name in ("simulation.md", "serving.md", "training.md"):
            assert f"docs/{name}" in readme
        training = (ROOT / "docs/training.md").read_text()
        assert "simulation.md" in training and "serving.md" in training
        assert "training.md" in (ROOT / "docs/simulation.md").read_text()
        assert "training.md" in (ROOT / "docs/serving.md").read_text()


class TestCheckerCatchesBreakage:
    def test_broken_link_reported(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("see [gone](no_such_file.md)\n")
        errors = cdl.check_file(md)
        assert len(errors) == 1 and "broken link" in errors[0]

    def test_missing_anchor_reported(self, tmp_path):
        (tmp_path / "t.md").write_text("# Only Heading\n")
        md = tmp_path / "x.md"
        md.write_text("see [t](t.md#other-heading)\n")
        errors = cdl.check_file(md)
        assert len(errors) == 1 and "missing anchor" in errors[0]

    def test_valid_anchor_accepted(self, tmp_path):
        (tmp_path / "t.md").write_text("## The Quantization, Tolerance!\n")
        md = tmp_path / "x.md"
        md.write_text("see [t](t.md#the-quantization-tolerance)\n")
        assert cdl.check_file(md) == []

    def test_dangling_code_path_reported(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("pinned by `tests/test_does_not_exist.py`\n")
        errors = cdl.check_file(md)
        assert len(errors) == 1 and "dangling code path" in errors[0]

    def test_non_path_tokens_ignored(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("math `T/2`, attr `A.P_io1/P_io2`, flag "
                      "`--x/--no-x`, module `energy/meter`\n")
        assert cdl.check_file(md) == []

    def test_fenced_blocks_stripped(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("```bash\ncat fake/path.py\n```\n")
        assert cdl.check_file(md) == []
