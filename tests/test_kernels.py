"""Pallas kernels vs pure-jnp oracles (interpret mode), sweeping shapes and
dtypes per the deliverable-(c) requirement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.kernels.quant_blockwise import quantize, dequantize
from repro.kernels.rglru_scan import rglru_scan as rg_raw

I = dict(force_interpret=True)


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.key(key), shape).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

class TestFlashAttention:
    @pytest.mark.parametrize("S,Dh,dtype", [
        (256, 128, jnp.float32), (512, 128, jnp.float32),
        (256, 256, jnp.float32), (256, 128, jnp.bfloat16)])
    @pytest.mark.parametrize("mode,w,c", [
        ("causal", 0, 0), ("sliding", 128, 0), ("chunked", 0, 128),
        ("bidir", 0, 0)])
    def test_matches_ref(self, S, Dh, dtype, mode, w, c):
        q, k, v = (rand(i, (3, S, Dh), dtype) for i in range(3))
        out = fa_raw(q, k, v, mode=mode, window=w, chunk=c, qb=128, kb=128,
                     interpret=True)
        r = ref.attention_ref(
            q[:, None].swapaxes(1, 1).reshape(3, 1, S, Dh),
            k.reshape(3, 1, S, Dh), v.reshape(3, 1, S, Dh),
            causal=(mode != "bidir"), window=w, chunk=c).reshape(3, S, Dh)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(r, np.float32), atol=tol,
                                   rtol=tol)

    def test_model_layout_wrapper(self):
        B, S, H, Dh = 2, 256, 4, 128
        q, k, v = (rand(i, (B, S, H, Dh), jnp.float32) for i in range(3))
        out = ops.flash_attention(q, k, v, mode="causal", **I)
        r = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=True).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=1e-4)

    def test_matches_model_attention_path(self):
        """Kernel == the model's XLA online-softmax path (two independent
        implementations of the same math)."""
        from repro.models.attention import attention
        B, S, H, Dh = 2, 512, 2, 128
        q, k, v = (rand(i + 10, (B, S, H, Dh), jnp.float32) for i in range(3))
        xla = attention(q, k, v, mode="causal")
        pal = ops.flash_attention(q, k, v, mode="causal", **I)
        np.testing.assert_allclose(np.asarray(pal), np.asarray(xla),
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

class TestRGLRU:
    @pytest.mark.parametrize("B,S,W", [(4, 512, 256), (8, 256, 128),
                                       (2, 1024, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, S, W, dtype):
        a = jax.nn.sigmoid(rand(0, (B, S, W), jnp.float32) - 1.0).astype(
            dtype)
        b = rand(1, (B, S, W), dtype)
        h0 = rand(2, (B, W), jnp.float32)
        out = ops.rglru_scan(a, b, h0, **I)
        r = ref.rglru_ref(a, b, h0)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(r, np.float32), atol=tol,
                                   rtol=tol)

    def test_carry_across_time_blocks(self):
        """sb smaller than S: the carry must flow across grid steps."""
        B, S, W = 2, 512, 128
        a = jnp.full((B, S, W), 0.9)
        b = jnp.ones((B, S, W)) * 0.1
        h0 = jnp.zeros((B, W))
        out = rg_raw(a, b, h0, bb=2, sb=64, wb=128, interpret=True)
        r = ref.rglru_ref(a, b, h0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=1e-5)

    def test_matches_model_rglru_core(self):
        """Kernel == the model's associative_scan implementation."""
        from repro.models.recurrent import _rglru_core
        from repro.models.spec import init_tree
        from repro.models.recurrent import rglru_spec
        from repro.configs import get_config, reduced
        cfg = reduced(get_config("recurrentgemma-9b"), d_model=128)
        p = init_tree(rglru_spec(cfg), jax.random.key(0))
        B, S, W = 2, 256, cfg.lru_width
        xw = rand(5, (B, S, W), jnp.float32) * 0.1
        h0 = jnp.zeros((B, W))
        h_model, _ = _rglru_core(p, xw, h0)
        # reproduce (block-diagonal) gate math, then kernel-scan it
        import jax.numpy as jnp2
        nb, wb, _ = p["gate_a"].shape
        x4 = xw.reshape(B, S, nb, wb)
        r = jax.nn.sigmoid(jnp2.einsum("bshw,hwv->bshv", x4,
                                       p["gate_a"]).reshape(B, S, W)
                           + p["gate_a_b"])
        i = jax.nn.sigmoid(jnp2.einsum("bshw,hwv->bshv", x4,
                                       p["gate_x"]).reshape(B, S, W)
                           + p["gate_x_b"])
        log_a = -8.0 * jax.nn.softplus(p["lamb"]) * r
        a = jnp2.exp(log_a)
        beta = jnp2.sqrt(jnp2.maximum(1 - jnp2.exp(2 * log_a), 1e-12))
        b = beta * (i * xw)
        h_kernel = ops.rglru_scan(a, b, h0, **I)
        np.testing.assert_allclose(np.asarray(h_kernel),
                                   np.asarray(h_model), atol=1e-5)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class TestMLSTM:
    @pytest.mark.parametrize("S,Dh,chunk", [(512, 128, 128), (256, 128, 256),
                                            (512, 256, 64)])
    def test_matches_stepwise_ref(self, S, Dh, chunk):
        B, H = 2, 2
        q = rand(0, (B, H, S, Dh), jnp.float32) * Dh ** -0.5
        k = rand(1, (B, H, S, Dh), jnp.float32) * Dh ** -0.5
        v = rand(2, (B, H, S, Dh), jnp.float32)
        li = rand(3, (B, H, S), jnp.float32) * 0.5
        lf = jax.nn.log_sigmoid(rand(4, (B, H, S), jnp.float32) + 2.0)
        out = ops.mlstm_scan(q, k, v, li, lf, chunk=chunk, **I)
        r = ref.mlstm_ref(q, k, v, li, lf)
        np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=1e-4,
                                   rtol=1e-3)

    def test_chunk_invariance(self):
        """Different chunk sizes give the same function."""
        B, H, S, Dh = 1, 2, 256, 128
        q = rand(0, (B, H, S, Dh), jnp.float32) * Dh ** -0.5
        k = rand(1, (B, H, S, Dh), jnp.float32) * Dh ** -0.5
        v = rand(2, (B, H, S, Dh), jnp.float32)
        li = rand(3, (B, H, S), jnp.float32)
        lf = jax.nn.log_sigmoid(rand(4, (B, H, S), jnp.float32) + 1.0)
        o64 = ops.mlstm_scan(q, k, v, li, lf, chunk=64, **I)
        o256 = ops.mlstm_scan(q, k, v, li, lf, chunk=256, **I)
        np.testing.assert_allclose(np.asarray(o64), np.asarray(o256),
                                   atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# Blockwise quantization
# ---------------------------------------------------------------------------

class TestQuant:
    @pytest.mark.parametrize("shape", [(512, 512), (256, 128), (1024, 640)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_roundtrip_error_bound(self, shape, dtype):
        x = (rand(0, shape, jnp.float32) * 5).astype(dtype)
        q, s = quantize(x.astype(jnp.float32), interpret=True)
        back = dequantize(q, s, interpret=True)
        # absmax-int8: error <= scale/2 = absmax/254 per 128-block
        err = np.abs(np.asarray(back) - np.asarray(x, np.float32))
        bound = np.abs(np.asarray(x, np.float32)).reshape(
            shape[0], -1, 128).max(-1) / 254.0 + 1e-6
        assert (err.reshape(shape[0], -1, 128).max(-1) <= bound + 1e-5).all()

    def test_matches_ref(self):
        x = rand(1, (256, 512), jnp.float32)
        q, s = quantize(x, interpret=True)
        qr, sr = ref.quant_ref(x)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)

    def test_any_shape_wrapper(self):
        for shape in [(3, 7, 190), (1000,), (5, 999)]:
            x = rand(2, shape, jnp.float32) * 2
            q, s, pad = ops.quantize_array(x, **I)
            back = ops.dequantize_array(q, s, shape=shape, dtype="float32",
                                        pad=pad, **I)
            rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
            assert rel < 0.01, (shape, rel)
            assert q.dtype == jnp.int8
            # 4x compression vs f32 (payload only)
            assert q.nbytes <= x.nbytes / 4 + 1024

    def test_compression_ratio_for_checkpoints(self):
        """The paper-facing claim: int8 blockwise shrinks checkpoint payloads
        ~4x vs f32 (~2x vs bf16) at <1% RMS error."""
        x = rand(3, (4096, 512), jnp.float32)
        q, s, pad = ops.quantize_array(x, **I)
        payload = q.nbytes + s.nbytes
        assert payload < 0.3 * x.nbytes
        back = ops.dequantize_array(q, s, shape=x.shape, dtype="float32",
                                    pad=pad, **I)
        rms = float(jnp.sqrt(jnp.mean((back - x) ** 2))
                    / jnp.sqrt(jnp.mean(x ** 2)))
        assert rms < 0.01, rms


# ---------------------------------------------------------------------------
# Flash-decoding kernel
# ---------------------------------------------------------------------------

class TestDecodeAttention:
    @pytest.mark.parametrize("S,Dh,L", [(1024, 128, 1024), (1024, 128, 700),
                                        (512, 256, 64), (768, 128, 768)])
    def test_matches_ref(self, S, Dh, L):
        from repro.kernels.decode_attention import decode_attention
        BH = 4
        q1 = rand(0, (BH, 1, Dh), jnp.float32)
        k = rand(1, (BH, S, Dh), jnp.float32)
        v = rand(2, (BH, S, Dh), jnp.float32)
        out = decode_attention(q1, k, v, L, kb=256, interpret=True)
        r = ref.decode_ref(q1[:, 0].reshape(BH, 1, Dh), k[:, None],
                           v[:, None], length=L)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(r[:, 0]), atol=1e-4)

    def test_bf16(self):
        from repro.kernels.decode_attention import decode_attention
        BH, S, Dh = 2, 512, 128
        q1 = rand(0, (BH, 1, Dh), jnp.bfloat16)
        k = rand(1, (BH, S, Dh), jnp.bfloat16)
        v = rand(2, (BH, S, Dh), jnp.bfloat16)
        out = decode_attention(q1, k, v, S, interpret=True)
        r = ref.decode_ref(q1[:, 0].reshape(BH, 1, Dh), k[:, None],
                           v[:, None], length=S)
        np.testing.assert_allclose(np.asarray(out[:, 0], np.float32),
                                   np.asarray(r[:, 0], np.float32),
                                   atol=3e-2, rtol=3e-2)
