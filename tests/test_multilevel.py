"""Multilevel (buddy + PFS) checkpointing: model reductions, joint (T, m)
solver parity (scalar vs batched vs the single-level seed optima), engine
trajectory semantics (hand-computed + bit-for-bit m=1 oracle parity), and
the Monte-Carlo validation of the closed forms (2% acceptance gate).
"""
import numpy as np
import pytest

from repro.core import (CheckpointParams, MultilevelCheckpointParams,
                        MultilevelPowerParams, PowerParams,
                        EXASCALE_POWER_RHO55, EXASCALE_ML_POWER,
                        simulate_once, t_opt_time, t_opt_energy,
                        t_opt_time_multilevel, t_opt_energy_multilevel,
                        time_final, energy_final, phase_times,
                        ml_time_final, ml_energy_final, ml_phase_times,
                        ml_energy_final_prime, ml_K_dE_dT,
                        ml_energy_quadratic_coefficients,
                        evaluate, evaluate_multilevel, sweep_buddy_ratio)
from repro.sim import (MultilevelParamGrid, ParamGrid, ScheduledRNG,
                       buddy_ratio_grid, evaluate_multilevel_grid,
                       get_scenario, list_scenarios,
                       multilevel_grid_from_scenarios, simulate_grid_ml,
                       simulate_trajectories_ml)

CK = CheckpointParams(C=10.0, R=10.0, D=1.0, mu=300.0, omega=0.5)
PW = EXASCALE_POWER_RHO55

#: a genuine two-level operating point (cheap buddy, rare level loss).
ML = MultilevelCheckpointParams(C1=1.0, R1=1.0, C2=10.0, R2=10.0,
                                D1=0.5, D2=1.0, mu=300.0, q=0.1, omega=0.5)


def degenerate(q=0.0):
    """Levels collapsed onto the single-level CK (exact-reduction lift)."""
    return MultilevelCheckpointParams.from_single(CK, q=q)


DPW = MultilevelPowerParams.from_power(PW)


# ---------------------------------------------------------------------------
# Model reduction: m=1 / degenerate levels reproduce the single-level model
# ---------------------------------------------------------------------------

class TestModelReduction:
    TS = np.linspace(22.0, 250.0, 9)

    def test_m1_time_is_bit_identical(self):
        """T_final(T, m=1) == the seed time_final, exactly — any q."""
        for q in (0.0, 0.3, 1.0):
            got = ml_time_final(self.TS, 1, degenerate(q))
            want = time_final(self.TS, CK)
            assert np.array_equal(got, want)

    def test_m1_energy_reduces_exactly_at_q0(self):
        got = ml_energy_final(self.TS, 1, degenerate(0.0), DPW)
        want = energy_final(self.TS, CK, PW)
        np.testing.assert_allclose(got, want, rtol=1e-13)

    def test_m1_energy_reduces_at_any_q(self):
        """q only splits the (identical) levels -> 1-ulp wobble at most."""
        got = ml_energy_final(self.TS, 1, degenerate(0.4), DPW)
        want = energy_final(self.TS, CK, PW)
        np.testing.assert_allclose(got, want, rtol=1e-12)

    def test_degenerate_levels_any_m_reduce(self):
        """C1=C2, q=0: buddy periods are indistinguishable from deep ones,
        so every m reproduces the single-level expectations."""
        ph_sl = phase_times(self.TS, CK)
        for m in (2, 3, 7):
            ck = degenerate(0.0)
            np.testing.assert_allclose(ml_time_final(self.TS, m, ck),
                                       ph_sl.T_final, rtol=1e-13)
            ph = ml_phase_times(self.TS, m, ck)
            np.testing.assert_allclose(ph.T_cal, ph_sl.T_cal, rtol=1e-13)
            np.testing.assert_allclose(ph.T_io1 + ph.T_io2, ph_sl.T_io,
                                       rtol=1e-13)
            np.testing.assert_allclose(ph.T_down, ph_sl.T_down, rtol=1e-13)

    def test_phase_times_compose_to_energy(self):
        ph = ml_phase_times(60.0, 3, ML)
        e = (ph.T_cal * EXASCALE_ML_POWER.P_cal
             + ph.T_io1 * EXASCALE_ML_POWER.P_io1
             + ph.T_io2 * EXASCALE_ML_POWER.P_io2
             + ph.T_final * EXASCALE_ML_POWER.P_static)
        assert float(e) == pytest.approx(
            float(ml_energy_final(60.0, 3, ML, EXASCALE_ML_POWER)),
            rel=1e-12)

    def test_energy_prime_matches_finite_difference(self):
        for m in (1, 2, 5):
            for T in (40.0, 90.0):
                h = 1e-6 * T
                fd = (ml_energy_final(T + h, m, ML, EXASCALE_ML_POWER)
                      - ml_energy_final(T - h, m, ML, EXASCALE_ML_POWER)) \
                    / (2 * h)
                an = ml_energy_final_prime(T, m, ML, EXASCALE_ML_POWER)
                assert float(an) == pytest.approx(float(fd), rel=1e-6)

    def test_K_dE_dT_is_quadratic(self):
        """The §3.2 cancellation survives the two-level extension."""
        for m in (1, 2, 6):
            c2, c1, c0 = ml_energy_quadratic_coefficients(
                ML, EXASCALE_ML_POWER, m)
            lo, hi = ML.valid_period_range(m)
            for frac in (0.15, 0.55, 0.85):
                t = lo + frac * (hi - lo)
                q = float(ml_K_dE_dT(t, m, ML, EXASCALE_ML_POWER))
                assert q == pytest.approx(c2 * t**2 + c1 * t + c0,
                                          rel=1e-7, abs=1e-9)

    def test_param_validation(self):
        with pytest.raises(ValueError):
            MultilevelCheckpointParams(C1=1, R1=1, C2=10, R2=10, D1=1, D2=1,
                                       mu=300.0, q=1.5)
        with pytest.raises(ValueError):
            MultilevelCheckpointParams(C1=-1, R1=1, C2=10, R2=10, D1=1, D2=1,
                                       mu=300.0)
        with pytest.raises(ValueError):
            MultilevelPowerParams(P_static=0.0, P_cal=1, P_io1=1, P_io2=1)


# ---------------------------------------------------------------------------
# Scalar joint (T, m) solvers
# ---------------------------------------------------------------------------

class TestScalarSolvers:
    def test_m1_algo_t_reproduces_seed_exactly(self):
        for q in (0.0, 0.3):
            t, m = t_opt_time_multilevel(degenerate(q), m_max=1)
            assert m == 1 and t == t_opt_time(CK)

    def test_m1_algo_e_reproduces_seed(self):
        t, m = t_opt_energy_multilevel(degenerate(0.0), DPW, m_max=1)
        assert m == 1
        assert t == pytest.approx(t_opt_energy(CK, PW), rel=1e-12)

    def test_degenerate_levels_never_beat_single(self):
        """With C1=C2 and q=0 all m are equivalent; the solver must return
        the single-level optimum value (any m)."""
        t, m = t_opt_time_multilevel(degenerate(0.0), m_max=6)
        assert float(ml_time_final(t, m, degenerate(0.0))) == pytest.approx(
            float(time_final(t_opt_time(CK), CK)), rel=1e-12)

    def test_cheap_buddy_prefers_m_gt_1(self):
        t, m = t_opt_time_multilevel(ML, m_max=12)
        te, me = t_opt_energy_multilevel(ML, EXASCALE_ML_POWER, m_max=12)
        assert m > 1 and me > 1
        # and it strictly beats the forced single-level schedule:
        t1, _ = t_opt_time_multilevel(ML, m_max=1)
        assert float(ml_time_final(t, m, ML)) < float(
            ml_time_final(t1, 1, ML))

    def test_evaluate_multilevel_point(self):
        pt = evaluate_multilevel(ML, EXASCALE_ML_POWER, m_max=8)
        assert pt.time_ratio >= 1.0 and pt.energy_ratio >= 1.0
        # the buddy level must pay for itself vs the PFS-only seed model
        assert pt.time_vs_single < 1.0
        assert pt.energy_vs_single < 1.0
        assert 0.0 < pt.energy_saving < 1.0

    def test_no_valid_m_raises(self):
        bad = MultilevelCheckpointParams(C1=50.0, R1=50.0, C2=500.0,
                                         R2=500.0, D1=1, D2=1, mu=300.0,
                                         q=0.1, omega=0.0)
        with pytest.raises(ValueError):
            t_opt_time_multilevel(bad, m_max=4)


# ---------------------------------------------------------------------------
# Batched joint solver vs the scalar reference
# ---------------------------------------------------------------------------

class TestPerLevelOverlap:
    """Async deep flush (VELOC): omega1/omega2 split of the shared omega."""

    def test_shared_omega_reduces_bit_for_bit(self):
        split = MultilevelCheckpointParams(
            C1=1.0, R1=1.0, C2=10.0, R2=10.0, D1=0.5, D2=1.0,
            mu=300.0, q=0.1, omega=0.0, omega1=0.5, omega2=0.5)
        for m in (1, 2, 5, 9):
            for T in (20.0, 40.0, 80.0):
                assert ml_time_final(T, m, split) == ml_time_final(T, m, ML)
                assert ml_energy_final(T, m, split, DPW) == \
                    ml_energy_final(T, m, ML, DPW)

    def test_flush_window_and_hard_loss(self):
        ck = MultilevelCheckpointParams(
            C1=1.0, R1=1.0, C2=10.0, R2=10.0, D1=0.5, D2=1.0,
            mu=300.0, q=0.1, omega1=0.2, omega2=0.9)
        assert ck.flush_window(3) == pytest.approx(0.9 * 10.0)
        # a hard failure pays the in-flight deep write on top of D2 + R2
        assert ck.expected_fixed_loss(3) == pytest.approx(
            (1 - 0.1) * (0.5 + 1.0 + ck.C_omega_mean(3))
            + 0.1 * (1.0 + 10.0 + 0.9 * 10.0))

    def test_time_overhead_monotone_in_omega2(self):
        """More overlap never makes the critical path worse."""
        prev = None
        for w2 in (0.0, 0.3, 0.6, 0.9, 1.0):
            ck = MultilevelCheckpointParams(
                C1=1.0, R1=1.0, C2=10.0, R2=10.0, D1=0.5, D2=1.0,
                mu=300.0, q=0.1, omega1=0.0, omega2=w2)
            tf = float(ml_time_final(30.0, 6, ck))
            if prev is not None:
                assert tf < prev
            prev = tf

    def test_async_scalar_batched_parity(self):
        """Batched omega1 != omega2 grid point matches the scalar solver."""
        ck = MultilevelCheckpointParams(
            C1=1.0, R1=1.0, C2=10.0, R2=10.0, D1=0.5, D2=1.0,
            mu=300.0, q=0.1, omega1=0.2, omega2=0.9)
        grid = MultilevelParamGrid.from_params(
            ck, EXASCALE_ML_POWER).reshape((1,))
        res = evaluate_multilevel_grid(grid, m_values=tuple(range(1, 9)))
        pt = evaluate_multilevel(ck, EXASCALE_ML_POWER, m_max=8)
        tf_b = float(ml_time_final(res.T_time[0], int(res.m_time[0]), ck))
        tf_s = float(ml_time_final(pt.T_time, pt.m_time, ck))
        assert tf_b == pytest.approx(tf_s, rel=1e-9)
        e_b = float(ml_energy_final(res.T_energy[0], int(res.m_energy[0]),
                                    ck, EXASCALE_ML_POWER))
        e_s = float(ml_energy_final(pt.T_energy, pt.m_energy, ck,
                                    EXASCALE_ML_POWER))
        assert e_b == pytest.approx(e_s, rel=1e-9)
        assert res.time_ratio[0] == pytest.approx(pt.time_ratio, rel=1e-7)
        assert res.energy_ratio[0] == pytest.approx(pt.energy_ratio,
                                                    rel=1e-7)


class TestBatchedSolverParity:
    def test_grid_matches_scalar(self):
        ratios, qs = [0.05, 0.2, 1.0], [0.02, 0.1, 0.3]
        grid = buddy_ratio_grid(ratios, qs, mu_min=300.0)
        res = evaluate_multilevel_grid(grid, m_values=tuple(range(1, 9)))
        for i in range(len(ratios)):
            for j in range(len(qs)):
                ck, pw = grid.ckpt_at((i, j)), grid.power_at((i, j))
                pt = evaluate_multilevel(ck, pw, m_max=8)
                # objective values must agree tightly; the argmin cadence may
                # only differ where two m are near-ties, so compare the
                # realized objectives rather than m itself.
                tf_b = float(ml_time_final(res.T_time[i, j],
                                           int(res.m_time[i, j]), ck))
                tf_s = float(ml_time_final(pt.T_time, pt.m_time, ck))
                assert tf_b == pytest.approx(tf_s, rel=1e-9)
                e_b = float(ml_energy_final(res.T_energy[i, j],
                                            int(res.m_energy[i, j]), ck, pw))
                e_s = float(ml_energy_final(pt.T_energy, pt.m_energy, ck, pw))
                assert e_b == pytest.approx(e_s, rel=1e-9)
                assert res.time_ratio[i, j] == pytest.approx(pt.time_ratio,
                                                             rel=1e-7)
                assert res.energy_ratio[i, j] == pytest.approx(
                    pt.energy_ratio, rel=1e-7)
                assert res.time_vs_single[i, j] == pytest.approx(
                    pt.time_vs_single, rel=1e-7)

    def test_m1_reproduces_single_level_batched(self):
        """Degenerate grid at m_values=(1,) == the seed batched solver."""
        sl = ParamGrid.from_params(CK, PW).reshape((1,))
        grid = MultilevelParamGrid.from_single_level(sl, q=0.0)
        res = evaluate_multilevel_grid(grid, m_values=(1,))
        assert res.T_time[0] == pytest.approx(t_opt_time(CK), rel=1e-12)
        assert res.T_energy[0] == pytest.approx(t_opt_energy(CK, PW),
                                                rel=1e-9)
        pt = evaluate(CK, PW)
        assert res.time_ratio[0] == pytest.approx(pt.time_ratio, rel=1e-9)
        assert res.energy_ratio[0] == pytest.approx(pt.energy_ratio,
                                                    rel=1e-9)
        # degenerate levels: the "two-level" scheme IS the single-level one
        assert res.time_vs_single[0] == pytest.approx(1.0, rel=1e-9)
        assert res.energy_vs_single[0] == pytest.approx(1.0, rel=1e-9)

    def test_degenerate_grid_point_collapses(self):
        """C2 of the order of the MTBF: no valid period at any m."""
        bad = MultilevelCheckpointParams(C1=20.0, R1=20.0, C2=200.0,
                                         R2=200.0, D1=1, D2=1, mu=120.0,
                                         q=0.1, omega=0.5)
        g1 = multilevel_grid_from_scenarios(
            [get_scenario("multilevel_exascale")])
        g2 = MultilevelParamGrid.from_params(
            bad, EXASCALE_ML_POWER).reshape((1,))
        both = MultilevelParamGrid(
            **{f: np.concatenate([getattr(g1, f), getattr(g2, f)])
               for f in g1.fields()})
        res = evaluate_multilevel_grid(both, m_values=(1, 2, 4))
        assert res.valid[0] and not res.valid[1]
        assert res.time_ratio[1] == 1.0 and res.energy_ratio[1] == 1.0
        assert res.T_time[1] == both.C2[1] and res.m_time[1] == 1

    def test_infeasible_single_level_comparator_gives_nan(self):
        """Regression: a platform only the buddy level makes feasible (no
        valid PFS-only period) must report NaN vs-single ratios, not the
        garbage of the comparator's masked-out placeholder bracket."""
        ck = MultilevelCheckpointParams(C1=5.0, R1=5.0, C2=100.0, R2=100.0,
                                        D1=0.5, D2=1.0, mu=120.0, q=0.1,
                                        omega=0.0)
        lo, hi = ck.single_level().valid_period_range()
        assert hi <= lo             # PFS-only truly infeasible
        grid = MultilevelParamGrid.from_params(
            ck, EXASCALE_ML_POWER).reshape((1,))
        res = evaluate_multilevel_grid(grid, m_values=(1, 2, 3, 4))
        assert res.valid[0]         # ...but the two-level scheme works
        assert np.isnan(res.time_vs_single[0])
        assert np.isnan(res.energy_vs_single[0])
        pt = evaluate_multilevel(ck, EXASCALE_ML_POWER, m_max=4)
        assert np.isnan(pt.time_vs_single) and np.isnan(pt.energy_vs_single)
        # the genuine two-level outputs stay well-defined
        assert res.time_ratio[0] >= 1.0 and np.isfinite(res.Tf_time[0])
        assert pt.T_time == pytest.approx(float(res.T_time[0]), rel=1e-9)

    def test_tradeoff_sweep_engines_agree(self):
        ratios, qs = [0.1, 0.4], [0.05, 0.2]
        fast = sweep_buddy_ratio(ratios, qs, mu_minutes=300.0, m_max=6)
        slow = sweep_buddy_ratio(ratios, qs, mu_minutes=300.0, m_max=6,
                                 engine="scalar")
        for rf, rs in zip(fast, slow):
            for pf, ps in zip(rf, rs):
                assert pf.time_ratio == pytest.approx(ps.time_ratio,
                                                      rel=1e-7)
                assert pf.energy_ratio == pytest.approx(ps.energy_ratio,
                                                        rel=1e-7)


# ---------------------------------------------------------------------------
# Two-level engine: hand-computed trajectories + m=1 oracle parity
# ---------------------------------------------------------------------------

def _hand_grid():
    ck = MultilevelCheckpointParams(C1=1.0, R1=1.0, C2=2.0, R2=2.0,
                                    D1=0.5, D2=1.0, mu=1.0e9, q=0.5,
                                    omega=0.0)
    pw = MultilevelPowerParams(P_static=1.0, P_cal=2.0, P_io1=3.0,
                               P_io2=5.0, P_down=7.0)
    return MultilevelParamGrid.from_params(ck, pw).reshape((1,))


class TestEngineTrajectories:
    """T=10, C1=1, C2=2, m=2, omega=0 (blocking), T_base=40.

    Fault-free schedule: [cmp 9 | ck1 1 | cmp 8 | ck2 2] x 2, then 6 units
    of compute -> wall 46.  A failure at t=33 strikes the 4th period
    (k=1 compute), when committed1=26 (buddy, t=30) > committed2=17
    (deep, t=20) — so soft and hard recovery genuinely differ.
    """

    def _run(self, gaps, hard):
        tb = simulate_trajectories_ml(
            10.0, 2, _hand_grid(), T_base=40.0,
            gaps=np.asarray(gaps)[None, None, :],
            hard=np.asarray(hard)[None, None, :])
        assert not tb.truncated.any() and not tb.gaps_exhausted.any()
        return tb

    def test_fault_free(self):
        tb = self._run([1e9, 1e9], [False, False])
        assert tb.wall_time[0, 0] == 46.0
        assert tb.work_executed[0, 0] == 40.0
        assert tb.io1_time[0, 0] == 2.0      # two buddy writes
        assert tb.io2_time[0, 0] == 4.0      # two deep writes
        assert int(tb.n_ckpt1[0, 0]) == 2 and int(tb.n_ckpt2[0, 0]) == 2
        assert int(tb.n_failures[0, 0]) == 0

    def test_soft_failure_rolls_back_to_buddy(self):
        tb = self._run([33.0, 1e9], [False, False])
        # lose 3 work units (26 -> 29), resume at period k=1: recovery at
        # t=34.5, compute 8, ck2 2, compute 6 -> wall 50.5.
        assert tb.wall_time[0, 0] == 50.5
        assert tb.work_executed[0, 0] == 43.0
        assert tb.io1_time[0, 0] == 3.0      # 2 writes + R1 recovery
        assert tb.io2_time[0, 0] == 4.0      # 2 deep writes (one re-planned)
        assert tb.down_time[0, 0] == 0.5
        assert int(tb.n_failures[0, 0]) == 1
        assert int(tb.n_hard_failures[0, 0]) == 0

    def test_hard_failure_rolls_back_to_deep(self):
        tb = self._run([33.0, 1e9], [True, False])
        # lose 12 work units (17 -> 29), restart superperiod at k=0:
        # recovery at t=36, then cmp 9 | ck1 1 | cmp 8 | ck2 2 | cmp 6 -> 62.
        assert tb.wall_time[0, 0] == 62.0
        assert tb.work_executed[0, 0] == 52.0
        assert tb.io1_time[0, 0] == 3.0      # 2 writes + 1 re-executed write
        assert tb.io2_time[0, 0] == 6.0      # 2 deep writes + R2 recovery
        assert tb.down_time[0, 0] == 1.0
        assert int(tb.n_failures[0, 0]) == 1
        assert int(tb.n_hard_failures[0, 0]) == 1
        # energy composes the per-level powers
        want = (1.0 * 62.0 + 2.0 * 52.0 + 3.0 * 3.0 + 5.0 * 6.0 + 7.0 * 1.0)
        assert tb.energy[0, 0] == pytest.approx(want, rel=1e-12)

    def test_too_short_period_raises(self):
        with pytest.raises(ValueError):
            simulate_trajectories_ml(1.5, 2, _hand_grid(), T_base=40.0,
                                     n_trials=2)
        with pytest.raises(ValueError):
            simulate_trajectories_ml(10.0, 0, _hand_grid(), T_base=40.0,
                                     n_trials=2)


class TestEngineOracleParity:
    """m=1 + degenerate levels: bit-for-bit equal to the scalar single-level
    oracle under a shared failure schedule (hard flags are inert)."""

    @pytest.mark.parametrize("T", [40.0, 53.3])
    def test_m1_matches_scalar_oracle(self, T):
        sl = ParamGrid.from_params(CK, PW).reshape((1,))
        grid = MultilevelParamGrid.from_single_level(sl, q=0.3)
        rng = np.random.default_rng(123)
        gaps = rng.exponential(CK.mu, size=(1, 8, 64))
        hard = rng.random(size=(1, 8, 64)) < 0.3
        tb = simulate_trajectories_ml(T, 1, grid, T_base=4000.0, gaps=gaps,
                                      hard=hard)
        assert not tb.truncated.any()
        for k in range(gaps.shape[1]):
            ref = simulate_once(T, CK, PW, 4000.0, ScheduledRNG(gaps[0, k]))
            assert tb.wall_time[0, k] == ref.wall_time
            assert tb.energy[0, k] == ref.energy
            assert tb.work_executed[0, k] == ref.work_executed
            assert tb.io1_time[0, k] + tb.io2_time[0, k] == ref.io_time
            assert tb.down_time[0, k] == ref.down_time
            assert int(tb.n_failures[0, k]) == ref.n_failures
            assert int(tb.n_ckpt1[0, k] + tb.n_ckpt2[0, k]) \
                == ref.n_checkpoints


# ---------------------------------------------------------------------------
# Monte-Carlo validation of the closed forms (acceptance gate)
# ---------------------------------------------------------------------------

class TestMonteCarloValidation:
    """Batched (T, m) solvers vs the two-level Monte-Carlo engine: expected
    makespan and energy within 2% at both AlgoT and AlgoE optima across the
    multilevel scenario grid (first-order validity regime: m*T < mu)."""

    RATIOS = [0.1, 0.25]
    QS = [0.1, 0.3]

    @pytest.fixture(scope="class")
    def solved(self):
        grid = buddy_ratio_grid(self.RATIOS, self.QS, mu_min=600.0)
        res = evaluate_multilevel_grid(grid, m_values=(1, 2, 3, 4))
        return grid, res

    @pytest.mark.parametrize("algo", ["time", "energy"])
    def test_within_2pct(self, solved, algo):
        grid, res = solved
        Ts = res.T_time if algo == "time" else res.T_energy
        ms = res.m_time if algo == "time" else res.m_energy
        out = simulate_grid_ml(Ts, ms, grid, 4000.0, n_trials=400, seed=5)
        for i in range(len(self.RATIOS)):
            for j in range(len(self.QS)):
                t, m = float(Ts[i, j]), int(ms[i, j])
                ck = grid.ckpt_at((i, j))
                pw = grid.power_at((i, j))
                tf_model = float(ml_time_final(t, m, ck, 4000.0))
                e_model = float(ml_energy_final(t, m, ck, pw, 4000.0))
                assert abs(out["T_final"][i, j] / tf_model - 1) < 0.02, (
                    f"T_final off at ratio={self.RATIOS[i]} q={self.QS[j]}")
                assert abs(out["E_final"][i, j] / e_model - 1) < 0.02, (
                    f"E_final off at ratio={self.RATIOS[i]} q={self.QS[j]}")

    def test_solver_choice_beats_forced_single_level_in_simulation(self, solved):
        """The (T*, m*) choice must win IN THE SIMULATOR, not just in the
        model: lower measured makespan than the PFS-only optimum."""
        grid, res = solved
        sl = evaluate_multilevel_grid(grid, m_values=(1,))
        two = simulate_grid_ml(res.T_time, res.m_time, grid, 4000.0,
                               n_trials=300, seed=9)
        one = simulate_grid_ml(sl.T_time, sl.m_time, grid, 4000.0,
                               n_trials=300, seed=9)
        assert (two["T_final"] < one["T_final"]).all()


# ---------------------------------------------------------------------------
# Scenario registry
# ---------------------------------------------------------------------------

class TestMultilevelScenarios:
    def test_registry_contains_family(self):
        names = set(list_scenarios())
        assert {"multilevel_exascale", "multilevel_fig12",
                "multilevel_arch"} <= names

    def test_grid_views_roundtrip(self):
        grid = buddy_ratio_grid([0.1, 0.5], [0.05, 0.2, 0.4])
        assert grid.shape == (2, 3)
        ck = grid.ckpt_at((1, 2))
        assert ck.C1 == pytest.approx(5.0) and ck.q == pytest.approx(0.4)
        pw = grid.power_at((0, 0))
        assert pw.P_io2 == pytest.approx(100.0)
        assert pw.P_io1 < pw.P_io2

    def test_single_level_projection(self):
        grid = buddy_ratio_grid([0.1], [0.2])
        sl = grid.single_level()
        assert sl.C[0, 0] == grid.C2[0, 0]
        assert sl.P_io[0, 0] == grid.P_io2[0, 0]


# ---------------------------------------------------------------------------
# Benchmark regression gate (pure comparison logic)
# ---------------------------------------------------------------------------

class TestBenchRegressionGate:
    def _payload(self, speedup):
        return {"fig2_seed_grid": {"speedup_warm": speedup},
                "dense_grid": {"speedup_warm": speedup}}

    def test_within_budget_passes(self):
        from benchmarks.bench_sweep import check_regression
        # speedup halved is the limit; just above it passes
        assert check_regression(self._payload(12.0),
                                self._payload(6.1)) == []

    def test_speedup_drop_fails(self):
        from benchmarks.bench_sweep import check_regression
        bad = check_regression(self._payload(12.0), self._payload(4.0))
        assert len(bad) == 2 and "3.0x" in bad[0]

    def test_faster_than_baseline_passes(self):
        from benchmarks.bench_sweep import check_regression
        assert check_regression(self._payload(12.0),
                                self._payload(40.0)) == []

    def test_missing_gated_grid_fails_loudly(self):
        """A grid the committed baseline gates must not vanish silently."""
        from benchmarks.bench_sweep import check_regression
        partial = self._payload(12.0)
        del partial["dense_grid"]
        bad = check_regression(self._payload(12.0), partial)
        assert len(bad) == 1 and "missing" in bad[0]

    def test_unbaselined_payload_grid_fails_loudly(self):
        """A gated bench the baseline doesn't know is an UNGATED bench —
        it must fail until BENCH_sweep.json is regenerated with it."""
        from benchmarks.bench_sweep import check_regression
        pay = self._payload(12.0)
        pay["brand_new_bench"] = {"speedup_warm": 0.1}
        bad = check_regression(self._payload(12.0), pay)
        assert len(bad) == 1 and "brand_new_bench" in bad[0]
        assert "missing from the committed baseline" in bad[0]

    def test_ungated_reference_entry_is_skipped(self):
        """Entries flagged ``ungated`` (the step-kernel reference) are
        excluded from the gate by design: an arbitrarily low ratio must
        not fail, and their presence on either side must not trip the
        missing/unbaselined checks."""
        from benchmarks.bench_sweep import check_regression
        base = self._payload(12.0)
        base["weibull_step_engine_reference"] = {"speedup_warm": 0.48,
                                                 "ungated": True}
        pay = self._payload(12.0)
        pay["weibull_step_engine_reference"] = {"speedup_warm": 0.01,
                                                "ungated": True}
        assert check_regression(base, pay) == []
        # payload-only ungated entry: still no complaint (not gated)
        pay["another_reference"] = {"speedup_warm": 0.2, "ungated": True}
        assert check_regression(base, pay) == []
        # baseline-only ungated entry: likewise skipped
        base["old_reference"] = {"speedup_warm": 3.0, "ungated": True}
        assert check_regression(base, pay) == []

    def test_async_overlap_collapse_matches_committed_baseline(self):
        """The async-flush entry is DETERMINISTIC model arithmetic: a
        fresh measurement must reproduce the committed baseline's gated
        quantity exactly, and the collapse story must hold (overhead
        ratio > 2x, time-optimal cadence m* -> 1 at full overlap)."""
        import json
        from benchmarks.bench_sweep import (CANONICAL,
                                            _time_async_overlap_collapse)
        entry = _time_async_overlap_collapse(repeat=1)
        assert entry["speedup_warm"] > 2.0
        assert entry["m_opt_time"][-1] == 1
        assert all(b < a for a, b in zip(entry["time_overhead"],
                                         entry["time_overhead"][1:]))
        committed = json.loads(CANONICAL.read_text())
        assert entry["speedup_warm"] == pytest.approx(
            committed["async_overlap_collapse"]["speedup_warm"], rel=1e-12)
