"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, shape and finiteness assertions, and prefill+decode == full-forward
consistency."""
import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, reduced, get_config, list_configs
from repro.models import build
from repro.optim import AdamWConfig, init_state

ARCH_NAMES = [c.name for c in ALL_ARCHS]


def make_batch(cfg, key, batch=2, seq=64):
    ks = jax.random.split(key, 4)
    b = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size),
    }
    if cfg.n_prefix_tokens:
        b["prefix"] = 0.02 * jax.random.normal(
            ks[2], (batch, cfg.n_prefix_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        b["frames"] = 0.02 * jax.random.normal(
            ks[3], (batch, cfg.encoder_seq, cfg.d_model))
    return b


@pytest.fixture(scope="module")
def rigs():
    """Initialized reduced models, shared across tests in this module."""
    out = {}
    for full in ALL_ARCHS:
        cfg = reduced(full)
        m = build(cfg)
        # stable per-arch seed (hash() varies with PYTHONHASHSEED)
        params = m.init(jax.random.key(zlib.crc32(full.name.encode())))
        out[full.name] = (cfg, m, params)
    return out


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(rigs, name):
    cfg, m, params = rigs[name]
    batch = make_batch(cfg, jax.random.key(0))
    logits, _ = m.forward(params, batch["tokens"],
                          prefix=batch.get("prefix"),
                          frames=batch.get("frames"))
    S = batch["tokens"].shape[1] + (cfg.n_prefix_tokens or 0)
    assert logits.shape == (2, S, cfg.padded_vocab())
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_reduces_loss_no_nans(rigs, name):
    cfg, m, params = rigs[name]
    step = jax.jit(m.make_train_step(AdamWConfig(lr=1e-3, warmup_steps=1,
                                                 total_steps=100)))
    opt = init_state(params)
    batch = make_batch(cfg, jax.random.key(1))
    losses = []
    for _ in range(3):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses       # overfits one batch
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_full_forward(rigs, name):
    cfg, m, params = rigs[name]
    S = 32
    key = jax.random.key(2)
    toks = jax.random.randint(key, (2, S + 1), 0, cfg.vocab_size)
    batch = make_batch(cfg, key, seq=S)
    batch["tokens"] = toks[:, :S]
    total = S + (cfg.n_prefix_tokens or 0)
    logits_p, cache = m.prefill(params, batch, max_cache_seq=total + 8)
    lg, new_cache = m.decode_step(params, cache, toks[:, S:S + 1])
    logits_f, _ = m.forward(params, toks, prefix=batch.get("prefix"),
                            frames=batch.get("frames"))
    a = np.asarray(lg[:, 0], np.float32)
    b = np.asarray(logits_f[:, -1], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)
    # bf16 compute with different reduction orders between the banded/cache
    # attention paths reaches ~3.7% on sliding-window archs (1.5e-6 in f32);
    # everything else stays under the original 3% bound.
    tol = 5e-2 if cfg.attention == "sliding" else 3e-2
    assert err < tol, err
    assert int(new_cache["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_multi_step_decode_matches_forward(rigs, name):
    """Greedy-decode 4 tokens from a prefill; logits at each step must match
    the growing full forward (teacher-forced)."""
    cfg, m, params = rigs[name]
    S, n_new = 16, 4
    key = jax.random.key(3)
    toks = jax.random.randint(key, (1, S + n_new), 0, cfg.vocab_size)
    batch = make_batch(cfg, key, batch=1, seq=S)
    batch["tokens"] = toks[:, :S]
    total = S + (cfg.n_prefix_tokens or 0)
    _, cache = m.prefill(params, batch, max_cache_seq=total + n_new)
    dec = jax.jit(lambda p, c, t: m.decode_step(p, c, t))
    for i in range(n_new):
        lg, cache = dec(params, cache, toks[:, S + i:S + i + 1])
        full, _ = m.forward(params, toks[:, :S + i + 1],
                            prefix=batch.get("prefix"),
                            frames=batch.get("frames"))
        a = np.asarray(lg[:, 0], np.float32)
        b = np.asarray(full[:, -1], np.float32)
        err = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)
        assert err < 3e-2, (i, err)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_close_to_analytic(rigs, name):
    """Exact spec-tree count within 25% of the analytic estimate (sanity that
    neither is wildly wrong; they differ by head padding / block details)."""
    cfg, m, params = rigs[name]
    full = get_config(name)
    exact = build(full).param_count()
    analytic = full.param_count()
    assert 0.6 < exact / analytic < 1.67, (exact, analytic)


def test_full_configs_match_assignment():
    """The exact assigned hyper-parameters."""
    c = get_config("dbrx-132b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.n_experts, c.top_k) == (
        40, 6144, 48, 8, 10752, 100352, 16, 4)
    c = get_config("llama4-scout-17b-a16e")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.n_experts, c.top_k) == (
        48, 5120, 40, 8, 8192, 202048, 16, 1)
    c = get_config("whisper-tiny")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == (
        4, 384, 6, 1536, 51865)
    c = get_config("xlstm-125m")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == (
        12, 768, 4, 0, 50304)
    c = get_config("starcoder2-3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.window) == (30, 3072, 24, 2, 12288, 49152, 4096)
    c = get_config("codeqwen1.5-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 4096, 32, 32, 13440, 92416)
    c = get_config("deepseek-coder-33b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (62, 7168, 56, 8, 19200, 32256)
    c = get_config("granite-20b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (52, 6144, 48, 1, 24576, 49152)
    c = get_config("internvl2-1b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (24, 896, 14, 2, 4864, 151655)
    c = get_config("recurrentgemma-9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (38, 4096, 16, 1, 12288, 256000)
    assert len(list_configs()) == 10


def test_applicable_shapes_rules():
    """DESIGN.md §4 skip table: 34 runnable cells."""
    runnable = {c.name: [s.name for s in c.applicable_shapes()]
                for c in ALL_ARCHS}
    long_ok = {n for n, shapes in runnable.items() if "long_500k" in shapes}
    assert long_ok == {"llama4-scout-17b-a16e", "xlstm-125m",
                       "starcoder2-3b", "recurrentgemma-9b"}
    total = sum(len(v) for v in runnable.values())
    assert total == 34
    # every arch runs the three base shapes
    for n, shapes in runnable.items():
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_head_padding_is_exact():
    """Padding q-heads to a multiple must not change the function value."""
    base = reduced(get_config("deepseek-coder-33b"))
    cfg_pad = dataclasses.replace(base, head_pad_multiple=8)  # 4 -> 8 heads
    m0, m1 = build(base), build(cfg_pad)
    p1 = m1.init(jax.random.key(0))

    # copy the real-head slices from padded params into an unpadded tree
    p0_spec = m0.param_spec()

    def crop(spec, arr):
        slices = tuple(slice(0, s) for s in spec.shape)
        return arr[slices]
    p0 = jax.tree.map(crop, p0_spec, p1,
                      is_leaf=lambda x: hasattr(x, "logical"))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, base.vocab_size)
    l0, _ = m0.forward(p0, toks)
    l1, _ = m1.forward(p1, toks)
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32), atol=2e-2)


def test_moe_capacity_close_to_dense():
    """High capacity factor => capacity MoE ~= dense MoE (no drops)."""
    base = reduced(get_config("dbrx-132b"))
    m_dense = build(base)
    cfg_cap = dataclasses.replace(base, moe_impl="capacity")
    m_cap = build(cfg_cap)
    params = m_dense.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, base.vocab_size)
    from repro.models.moe import moe_capacity
    import repro.models.moe as moe_mod
    ld, _ = m_dense.forward(params, toks)
    # capacity path with generous factor
    import functools
    orig = moe_mod.moe_capacity
    moe_mod_capacity = functools.partial(orig, capacity_factor=4.0)
    try:
        moe_mod.moe_capacity = moe_mod_capacity
        lc, _ = m_cap.forward(params, toks)
    finally:
        moe_mod.moe_capacity = orig
    a, b = np.asarray(ld, np.float32), np.asarray(lc, np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)
    assert err < 0.05, err
