"""Compile/leak sanitizer tier (marker: ``sanitizer``).

Runs the canonical fig2 / multilevel / advisor sweeps under
``jax.checking_leaks`` and under a compile counter gated by the
recompile budget committed in ``BENCH_sweep.json`` — see
docs/contracts.md ("Sanitizer tier").  CI runs this file on its own via
``pytest -m sanitizer``; it also runs in the default suite.

The negative control proves the gate has teeth: a deliberately
shape-unbucketed sweep (one jit specialization per distinct input
length) must breach the committed budget and raise.
"""
import pytest

jax = pytest.importorskip("jax")

from repro import sanitize  # noqa: E402

pytestmark = pytest.mark.sanitizer

WORKLOADS = sorted(sanitize.CANONICAL_WORKLOADS)


@pytest.mark.parametrize("name", WORKLOADS)
def test_leak_clean(name):
    """No traced value escapes its trace on the canonical paths."""
    sanitize.run_leak_checked(sanitize.CANONICAL_WORKLOADS[name])


@pytest.mark.parametrize("name", WORKLOADS)
def test_recompile_budget(name):
    budgets = sanitize.load_budgets()
    if not budgets or name not in budgets:
        pytest.skip(f"no committed recompile budget for {name} — run "
                    "`python -m repro.sanitize --write`")
    measured = sanitize.measure_workload(sanitize.CANONICAL_WORKLOADS[name])
    sanitize.recompile_gate(name, measured, budgets)   # raises on breach
    assert measured <= budgets[name]["budget"]


def test_budget_schema():
    budgets = sanitize.load_budgets()
    if not budgets:
        pytest.skip("no committed recompile budget")
    for name in WORKLOADS:
        entry = budgets[name]
        assert entry["measured"] <= entry["budget"]
        # slack formula: committed budget = measured + max(4, 25%)
        assert entry["budget"] == entry["measured"] + max(
            4, -(-entry["measured"] // 4))


def _unbucketed_sweep():
    """The seed-era anti-pattern: a fresh shape per grid point, so jit
    specializes once per point instead of once per bucket."""
    import jax.numpy as jnp

    @jax.jit
    def point(x):
        return jnp.sum(x * 2.0)

    for n in (3, 5, 7, 11, 13, 17, 19, 23):
        point(jnp.zeros((n,))).block_until_ready()


def test_unbucketed_sweep_breaches_budget():
    budgets = sanitize.load_budgets()
    if not budgets or "fig2_small" not in budgets:
        pytest.skip("no committed recompile budget")
    measured = sanitize.measure_workload(_unbucketed_sweep)
    assert measured >= 8, "expected one compile per distinct shape"
    with pytest.raises(sanitize.RecompileBudgetError):
        sanitize.recompile_gate("fig2_small", measured, budgets)


def test_gate_is_noop_without_committed_budget(tmp_path):
    missing = tmp_path / "nothing.json"
    assert sanitize.load_budgets(missing) is None
    sanitize.recompile_gate("fig2_small", 10 ** 6, path=missing)
