"""Property-based tests (hypothesis) on the system's invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st, assume, HealthCheck

from repro.core import (CheckpointParams, PowerParams, energy_final,
                        time_final, t_opt_time, t_opt_time_numeric,
                        t_opt_energy, t_opt_energy_numeric,
                        energy_quadratic_coefficients,
                        Exponential, LogNormal, Weibull,
                        fig12_checkpoint, simulate_once,
                        EXASCALE_POWER_RHO55)
from repro.core.optimal import derived_coefficients
from repro.kernels import ref

SETTINGS = dict(max_examples=40, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])

# --- strategies -------------------------------------------------------------

ckpt_params = st.builds(
    CheckpointParams,
    C=st.floats(0.5, 20.0),
    R=st.floats(0.1, 20.0),
    D=st.floats(0.0, 5.0),
    mu=st.floats(100.0, 10_000.0),
    omega=st.floats(0.0, 0.95),
)

power_params = st.builds(
    PowerParams,
    P_static=st.floats(1.0, 50.0),
    P_cal=st.floats(0.1, 100.0),
    P_io=st.floats(0.1, 500.0),
    P_down=st.floats(0.0, 20.0),
)


class TestAnalyticalInvariants:
    @settings(**SETTINGS)
    @given(ckpt_params)
    def test_closed_form_time_optimum_is_argmin(self, ck):
        assume(ck.valid_period_range()[1] > ck.valid_period_range()[0] * 1.01)
        t_star = t_opt_time(ck)
        t_num = t_opt_time_numeric(ck)
        # the two optimizers agree...
        assert t_star == pytest.approx(t_num, rel=1e-4)
        # ...and perturbations never improve the objective
        f = lambda t: float(time_final(t, ck))
        lo, hi = ck.valid_period_range()
        for c in (0.8, 0.95, 1.05, 1.2):
            t = min(max(t_star * c, lo * 1.001), hi * 0.999)
            assert f(t_star) <= f(t) + 1e-9 * abs(f(t))

    @settings(**SETTINGS)
    @given(ckpt_params, power_params)
    def test_energy_root_is_argmin_and_quadratic_is_exact(self, ck, pw):
        assume(ck.valid_period_range()[1] > ck.valid_period_range()[0] * 1.01)
        te = t_opt_energy(ck, pw)
        tn = t_opt_energy_numeric(ck, pw)
        e = lambda t: float(energy_final(t, ck, pw))
        assert e(te) <= e(tn) * (1 + 1e-6)
        # interpolated quadratic == closed-form derived coefficients
        qi = energy_quadratic_coefficients(ck, pw)
        qd = derived_coefficients(ck, pw)
        for a, b in zip(qi, qd):
            assert a == pytest.approx(b, rel=1e-6, abs=1e-12)

    @settings(**SETTINGS)
    @given(ckpt_params, power_params)
    def test_energy_never_below_static_floor(self, ck, pw):
        assume(ck.valid_period_range()[1] > ck.valid_period_range()[0] * 1.01)
        te = t_opt_energy(ck, pw)
        # E >= P_static * T_final >= P_static * T_base
        assert float(energy_final(te, ck, pw)) >= pw.P_static * 1.0

    @settings(**SETTINGS)
    @given(ckpt_params)
    def test_more_failures_longer_runtime(self, ck):
        """T_final is monotonically decreasing in mu at fixed T."""
        assume(ck.valid_period_range()[1] > ck.valid_period_range()[0] * 1.01)
        t = t_opt_time(ck)
        worse = CheckpointParams(C=ck.C, R=ck.R, D=ck.D, mu=ck.mu / 2,
                                 omega=ck.omega)
        lo, hi = worse.valid_period_range()
        assume(lo * 1.01 < t < hi * 0.99)
        assert float(time_final(t, worse)) > float(time_final(t, ck))


class TestFailureProcessProperties:
    """Every failure process's sampled gap mean converges to its declared
    mu, and exponential instances reproduce the legacy paths bit-for-bit."""

    @settings(**SETTINGS)
    @given(st.sampled_from(["exponential", "weibull", "lognormal"]),
           st.floats(0.45, 2.5), st.floats(10.0, 1000.0),
           st.integers(0, 2**31 - 1))
    def test_sampled_gap_mean_converges_to_mu(self, name, shape, mu, seed):
        if name == "weibull":
            proc = Weibull(shape=shape)
        elif name == "lognormal":
            proc = LogNormal(sigma=min(shape, 1.3))
        else:
            proc = Exponential()
        n = 50_000
        g = proc.sample(np.random.default_rng(seed), size=(n,), mean=mu)
        cv = float(np.max(np.asarray(proc.gap_cv())))
        # 8 sigma of the sample mean: astronomically unlikely to flake while
        # still catching any mis-scaled parameterization (which shifts the
        # mean by O(10%+)).
        assert abs(float(g.mean()) - mu) < 8.0 * cv * mu / math.sqrt(n)
        assert (g > 0).all()

    @settings(**SETTINGS)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 6),
           st.integers(4, 64))
    def test_exponential_presample_bit_for_bit(self, seed, n_trials, cap):
        from repro.sim import ParamGrid
        from repro.sim.engine import presample_gaps
        grid = ParamGrid.from_params(fig12_checkpoint(300.0),
                                     EXASCALE_POWER_RHO55).reshape((1,))
        legacy = presample_gaps(grid, n_trials, cap, seed=seed)
        via = presample_gaps(grid, n_trials, cap, seed=seed,
                             process=Exponential())
        np.testing.assert_array_equal(legacy, via)

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(0, 2**31 - 1), st.floats(40.0, 120.0))
    def test_exponential_simulate_once_bit_for_bit(self, seed, T):
        ck = fig12_checkpoint(300.0)
        r1 = simulate_once(T, ck, EXASCALE_POWER_RHO55, 1500.0,
                           np.random.default_rng(seed))
        r2 = simulate_once(T, ck, EXASCALE_POWER_RHO55, 1500.0,
                           np.random.default_rng(seed),
                           process=Exponential())
        assert r1 == r2


class TestKernelProperties:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 4), st.sampled_from([128, 256]),
           st.sampled_from([128, 256]))
    def test_flash_attention_rows_sum_to_convex_combination(self, b, s, dh):
        """Attention outputs are convex combinations of V rows: outputs are
        bounded by V's min/max per dim."""
        q = jax.random.normal(jax.random.key(0), (b, s, dh))
        k = jax.random.normal(jax.random.key(1), (b, s, dh))
        v = jax.random.normal(jax.random.key(2), (b, s, dh))
        from repro.kernels.flash_attention import flash_attention
        out = flash_attention(q, k, v, mode="causal", qb=128, kb=128,
                              interpret=True)
        vmax = np.asarray(v).max(axis=1, keepdims=True)
        vmin = np.asarray(v).min(axis=1, keepdims=True)
        o = np.asarray(out)
        assert (o <= vmax + 1e-4).all() and (o >= vmin - 1e-4).all()

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_quant_roundtrip_error_bound_random(self, seed):
        x = jax.random.normal(jax.random.key(seed), (64, 256)) * \
            (10.0 ** jax.random.uniform(jax.random.key(seed + 1), (), minval=-3, maxval=3))
        q, s = ref.quant_ref(np.asarray(x))
        back = ref.dequant_ref(q, s)
        blocks = np.asarray(x).reshape(64, -1, 128)
        bound = np.abs(blocks).max(-1, keepdims=True) / 127.0 * 0.5 + 1e-9
        err = np.abs(np.asarray(back).reshape(64, -1, 128) - blocks)
        assert (err <= bound + 1e-6).all()

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 1000))
    def test_rglru_decay_bounds_state(self, seed):
        """With |a|<1 and bounded inputs, the linear scan stays bounded by
        max|b|/(1-max|a|) + |h0|."""
        key = jax.random.key(seed)
        a = jax.nn.sigmoid(jax.random.normal(key, (2, 128, 64)))
        a = jnp.minimum(a, 0.95)
        b = jax.random.normal(jax.random.key(seed + 1), (2, 128, 64))
        h0 = jnp.zeros((2, 64))
        h = ref.rglru_ref(a, b, h0)
        bound = float(jnp.max(jnp.abs(b))) / (1 - 0.95) + 1e-3
        assert float(jnp.max(jnp.abs(h))) <= bound


class TestDataPipelineProperties:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000), st.integers(0, 100))
    def test_batches_are_pure_functions_of_state(self, seed, step):
        from repro.data import SyntheticLM, DataConfig
        cfg = DataConfig(vocab_size=512, batch=2, seq_len=16, seed=seed)
        d1 = SyntheticLM(cfg, step=step)
        d2 = SyntheticLM(cfg, step=step)
        b1, b2 = d1.peek(), d2.peek()
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        # tokens in range
        t = np.asarray(b1["tokens"])
        assert (t >= 0).all() and (t < 512).all()

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 50), st.integers(1, 20))
    def test_restore_resumes_exact_stream(self, start, advance):
        from repro.data import SyntheticLM, DataConfig
        cfg = DataConfig(vocab_size=128, batch=2, seq_len=8, seed=7)
        d = SyntheticLM(cfg, step=start)
        state = d.state()
        stream1 = [np.asarray(next(d)["tokens"]) for _ in range(advance)]
        d2 = SyntheticLM(cfg)
        d2.restore(state)
        stream2 = [np.asarray(next(d2)["tokens"]) for _ in range(advance)]
        for a, b in zip(stream1, stream2):
            np.testing.assert_array_equal(a, b)


class TestShardingProperties:
    @settings(max_examples=30, deadline=None)
    @given(st.sampled_from(["batch", "vocab", "heads", "mlp", "experts"]),
           st.integers(1, 64))
    def test_resolution_never_breaks_divisibility(self, name, dim):
        from repro.parallel.sharding import resolve_pspec
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(len(jax.devices()))
        spec = resolve_pspec((name,), mesh, shape=(dim,))
        size = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                size *= mesh.shape[a]
        assert dim % size == 0


class TestAdvisorQuantizationContract:
    """serve.fingerprint's tolerance contract, hypothesis-driven.

    For arbitrary platforms, the answer served from the quantized-key
    cache must cost at most ``(1 + cert_bound)`` times the exact
    per-request optimum in the served objective — with ``cert_bound``
    within the documented tolerance whenever the cache was allowed to
    serve it (uncertifiable cells fall back to exact solves, so the
    contract holds unconditionally).  The seeded-random sweep (including
    multilevel (T, m)) lives in tests/test_advisor.py.
    """

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ckpt_params, power_params,
           st.sampled_from(["time", "energy"]))
    def test_cached_answer_within_documented_tolerance(self, ck, pw, obj):
        from repro.serve import AdviceRequest, AdvisorService, Quantization
        from repro.sim.sweep import (energy_final_batched,
                                     time_final_batched)

        req = AdviceRequest.from_params(ck, pw, objective=obj)
        quant = AdvisorService(cache_name=None)
        exact = AdvisorService(
            quantization=Quantization(rel=0.0, absolute=0.0),
            cache_name=None)
        a, t = quant.advise(req), exact.advise(req)
        assume(a.valid and t.valid)
        if not a.exact:
            assert a.cert_bound <= quant.quant.tol

        p = dict(C=ck.C, R=ck.R, D=ck.D, mu=ck.mu, omega=ck.omega,
                 P_static=pw.P_static, P_cal=pw.P_cal, P_io=pw.P_io,
                 P_down=pw.P_down)
        J = (time_final_batched if obj == "time"
             else energy_final_batched)
        assert float(J(a.period, p)) <= float(J(t.period, p)) * (
            1.0 + max(a.cert_bound, 1e-12))
