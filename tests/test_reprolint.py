"""reprolint: every rule caught by its positive fixture, silent on its
negative fixture, suppression syntax + RPL006 hygiene, CLI exit codes,
and the repo-clean gate (the whole repo lints clean inside tier-1).

The linter is pure stdlib ast — no jax import anywhere in this file.
"""
import subprocess
import sys
from pathlib import Path

from repro.lint import ALL_RULES, ModuleInfo, run_lint
from repro.lint.hotpath import CallGraph, rule_rpl004
from repro.lint.rules import (rule_rpl001, rule_rpl002, rule_rpl003,
                              rule_rpl005)

ROOT = Path(__file__).resolve().parents[1]
FIX = ROOT / "tests" / "fixtures" / "lint"

#: the issue's documented-suppression budget for the repo-clean gate.
SUPPRESSION_BUDGET = 15


class _Ctx:
    """Minimal RepoContext stand-in: rules only touch .modules."""

    def __init__(self, infos):
        self.modules = list(infos)
        self.by_module = {i.module: i for i in infos if i.module}
        self.errors = []


def _info(name, rel=None):
    """Parse a fixture, optionally under a synthetic repo-relative path
    (how the path-gated rules are pointed at src/-only checks)."""
    p = FIX / name
    return ModuleInfo(p, rel or f"tests/fixtures/lint/{name}", p.read_text())


def _codes(diags):
    return [d.code for d in sorted(diags, key=lambda d: (d.line, d.col))]


# ---------------------------------------------------------------------------
# RPL001 — randomness
# ---------------------------------------------------------------------------


class TestRPL001:
    def test_positive(self):
        diags = rule_rpl001(_Ctx([_info("rpl001_pos.py")]))
        assert _codes(diags) == ["RPL001"] * 4
        msgs = " ".join(d.message for d in diags)
        assert "unseeded" in msgs
        assert "wall-clock" in msgs
        assert "global state" in msgs or "global-state" in msgs

    def test_negative(self):
        assert rule_rpl001(_Ctx([_info("rpl001_neg.py")])) == []

    def test_seeded_rng_outside_approved_sites(self):
        """The same clean file becomes one violation under a src/ path
        that is not on the allowlist."""
        info = _info("rpl001_neg.py", rel="src/repro/core/fixture.py")
        diags = rule_rpl001(_Ctx([info]))
        assert _codes(diags) == ["RPL001"]
        assert "approved sites" in diags[0].message

    def test_allowlisted_site_stays_clean(self):
        info = _info("rpl001_neg.py", rel="src/repro/sim/engine.py")
        assert rule_rpl001(_Ctx([info])) == []


# ---------------------------------------------------------------------------
# RPL002 — caches
# ---------------------------------------------------------------------------


class TestRPL002:
    def test_positive(self):
        diags = rule_rpl002(_Ctx([_info("rpl002_pos.py")]))
        # functools.cache, lru_cache(maxsize=None), LRUCache without name=
        assert _codes(diags) == ["RPL002"] * 3

    def test_dict_cache_flagged_under_src(self):
        info = _info("rpl002_pos.py", rel="src/repro/sim/fixture.py")
        diags = rule_rpl002(_Ctx([info]))
        assert _codes(diags) == ["RPL002"] * 4
        assert any("_RESULT_CACHE" in d.message for d in diags)

    def test_negative(self):
        info = _info("rpl002_neg.py", rel="src/repro/sim/fixture.py")
        assert rule_rpl002(_Ctx([info])) == []


# ---------------------------------------------------------------------------
# RPL003 — dtype contract
# ---------------------------------------------------------------------------


class TestRPL003:
    def test_positive_under_f64_subsystem(self):
        info = _info("rpl003_pos.py", rel="src/repro/sim/fixture.py")
        diags = rule_rpl003(_Ctx([info]))
        # zeros, arange, asarray without dtype; jnp.float32; "float32"
        assert _codes(diags) == ["RPL003"] * 5

    def test_path_gating(self):
        """The same file outside sim/core/serve is not the rule's business."""
        assert rule_rpl003(_Ctx([_info("rpl003_pos.py")])) == []
        info = _info("rpl003_pos.py", rel="src/repro/models/fixture.py")
        assert rule_rpl003(_Ctx([info])) == []

    def test_negative(self):
        info = _info("rpl003_neg.py", rel="src/repro/core/fixture.py")
        assert rule_rpl003(_Ctx([info])) == []

    def test_precision_module_allowance(self):
        """float32 is legal in sim/ ONLY under the PrecisionPolicy module."""
        # Same content, non-policy sim/ path: both references flag.
        info = _info("rpl003_precision_pos.py",
                     rel="src/repro/sim/fixture.py")
        diags = rule_rpl003(_Ctx([info]))
        assert _codes(diags) == ["RPL003"] * 2
        assert any("PrecisionPolicy" in d.message for d in diags)

    def test_precision_module_is_clean(self):
        info = _info("rpl003_precision_neg.py",
                     rel="src/repro/sim/precision.py")
        assert rule_rpl003(_Ctx([info])) == []

    def test_precision_module_still_needs_explicit_dtypes(self):
        """The allowance waives the float32 checks, not the
        explicit-dtype constructor check."""
        info = _info("rpl003_pos.py", rel="src/repro/sim/precision.py")
        diags = rule_rpl003(_Ctx([info]))
        # zeros/arange/asarray without dtype still flag; the jnp.float32
        # attribute and the "float32" string are now legal.
        assert _codes(diags) == ["RPL003"] * 3

    def test_real_precision_module_is_clean(self):
        src = ROOT / "src/repro/sim/precision.py"
        info = ModuleInfo(src, "src/repro/sim/precision.py",
                          src.read_text())
        assert rule_rpl003(_Ctx([info])) == []


# ---------------------------------------------------------------------------
# RPL004 — host sync on jit-reachable paths
# ---------------------------------------------------------------------------


class TestRPL004:
    def test_positive(self):
        diags = rule_rpl004(_Ctx([_info("rpl004_pos.py")]))
        # .item(), np.asarray, float() in bad_step; .tolist() in helper
        assert _codes(diags) == ["RPL004"] * 4
        assert any("helper" in d.message for d in diags), \
            "helper must be reached through the call graph, not just roots"

    def test_negative(self):
        assert rule_rpl004(_Ctx([_info("rpl004_neg.py")])) == []

    def test_graph_shape(self):
        graph = CallGraph(_Ctx([_info("rpl004_pos.py"),
                                _info("rpl004_neg.py")]))
        reachable = {f for _, f in graph.reachable}
        assert {"bad_step", "calls_helper", "helper",
                "good_step"} <= reachable
        assert "host_report" not in reachable

    def test_thread_targets_are_roots(self):
        """Worker bodies handed to threading.Thread(target=...) are
        rooted — plain-function and ``target=self._method`` shapes."""
        diags = rule_rpl004(_Ctx([_info("rpl004_thread_pos.py")]))
        assert _codes(diags) == ["RPL004"] * 2
        msgs = " ".join(d.message for d in diags)
        assert "_flush_body" in msgs and "_drain" in msgs

    def test_non_thread_target_keyword_not_rooted(self):
        assert rule_rpl004(_Ctx([_info("rpl004_thread_neg.py")])) == []


# ---------------------------------------------------------------------------
# RPL005 — Python branching in scan bodies
# ---------------------------------------------------------------------------


class TestRPL005:
    def test_positive(self):
        diags = rule_rpl005(_Ctx([_info("rpl005_pos.py")]))
        assert _codes(diags) == ["RPL005"] * 2
        kinds = {d.message.split("`")[1] for d in diags}
        assert kinds == {"if", "while"}

    def test_negative(self):
        assert rule_rpl005(_Ctx([_info("rpl005_neg.py")])) == []


# ---------------------------------------------------------------------------
# suppressions + RPL006 hygiene (engine level, real fixture paths)
# ---------------------------------------------------------------------------


class TestSuppressions:
    def test_same_line_and_own_line_forms_mask(self):
        res = run_lint(ROOT, paths=[FIX / "suppressed.py"])
        assert res.suppressed == 2
        # only the unused suppression survives, as RPL006
        assert _codes(res.diagnostics) == ["RPL006"]
        assert "unused suppression" in res.diagnostics[0].message

    def test_missing_reason_is_flagged(self):
        res = run_lint(ROOT, paths=[FIX / "missing_reason.py"])
        assert res.suppressed == 1          # the RPL002 itself is masked
        assert _codes(res.diagnostics) == ["RPL006"]
        assert "without a reason" in res.diagnostics[0].message

    def test_file_level_form(self):
        res = run_lint(ROOT, paths=[FIX / "file_level.py"])
        assert res.ok
        assert res.suppressed == 2

    def test_select_filters_codes(self):
        res = run_lint(ROOT, paths=[FIX / "rpl001_pos.py"],
                       select=["RPL002"])
        assert res.ok                        # RPL001 hits filtered out
        res = run_lint(ROOT, paths=[FIX / "rpl001_pos.py"],
                       select=["RPL001"])
        assert len(res.diagnostics) == 4


# ---------------------------------------------------------------------------
# CLI + repo-clean gate
# ---------------------------------------------------------------------------


def _cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.lint", "--root", str(ROOT), *args],
        capture_output=True, text=True, cwd=ROOT)


class TestCLI:
    def test_violations_exit_1(self):
        proc = _cli(str(FIX / "rpl001_pos.py"))
        assert proc.returncode == 1
        assert "RPL001" in proc.stdout

    def test_select_flag(self):
        proc = _cli(str(FIX / "rpl001_pos.py"), "--select", "RPL002")
        assert proc.returncode == 0

    def test_list_suppressions(self):
        proc = _cli(str(FIX / "suppressed.py"), "--list-suppressions")
        assert proc.returncode == 0
        assert "disable=RPL002" in proc.stdout


class TestRepoClean:
    """The tier-1 contract: the repo itself lints clean, with every
    suppression documented and inside the budget."""

    def test_repo_is_clean(self):
        res = run_lint(ROOT, rules=ALL_RULES)
        assert res.ok, "\n".join(d.render() for d in res.diagnostics)

    def test_suppression_budget(self):
        res = run_lint(ROOT)
        assert len(res.suppressions) <= SUPPRESSION_BUDGET
        for s in res.suppressions:
            assert s.reason, f"{s.path}:{s.line} suppression lacks a reason"
            assert s.used, f"{s.path}:{s.line} suppression is unused"

    def test_fixtures_excluded_by_default(self):
        """The deliberate fixture violations never leak into the gate."""
        res = run_lint(ROOT)
        assert not any(d.path.startswith("tests/fixtures/lint")
                       for d in res.diagnostics)


def test_unparseable_file_reports_rpl999(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def broken(:\n")
    res = run_lint(tmp_path, paths=[bad])
    assert _codes(res.diagnostics) == ["RPL999"]
