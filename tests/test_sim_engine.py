"""Parity tests: batched ``repro.sim`` vs the scalar reference oracles.

- engine vs ``simulate_once`` trajectory-for-trajectory under a shared
  failure schedule (ScheduledRNG replays the same exponential gaps),
- engine means vs scalar ``simulate`` within 3 standard errors on registry
  scenarios,
- batched period solvers vs the scalar ``optimal`` solvers across a grid,
- the t_opt_energy root-selection guard (regression for the silent
  maximum-root pick).
"""
import math

import numpy as np
import pytest

from repro.core import (CheckpointParams, PowerParams, EXASCALE_POWER_RHO55,
                        simulate, simulate_once, t_opt_time, t_opt_energy,
                        t_opt_energy_numeric, t_young, t_daly, t_msk_energy,
                        evaluate, fig12_checkpoint)
from repro.core import model, optimal
from repro.sim import (ParamGrid, ScheduledRNG, get_scenario, list_scenarios,
                       grid_from_scenarios, mu_rho_grid, nodes_grid,
                       simulate_grid, simulate_trajectories, evaluate_grid)


CK = fig12_checkpoint(300.0)
PW = EXASCALE_POWER_RHO55


# ---------------------------------------------------------------------------
# Engine vs scalar oracle
# ---------------------------------------------------------------------------

class TestTrajectoryParity:
    """Shared failure schedule -> identical trajectories (both kernels)."""

    @pytest.mark.parametrize("engine_kind", ["step", "event"])
    @pytest.mark.parametrize("T", [40.0, 53.3, 90.0])
    def test_single_scenario_matches_oracle(self, T, engine_kind):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        rng = np.random.default_rng(123)
        gaps = rng.exponential(CK.mu, size=(1, 8, 64))
        tb = simulate_trajectories(T, grid, T_base=4000.0, gaps=gaps,
                                   engine_kind=engine_kind)
        assert not tb.truncated.any()
        for k in range(gaps.shape[1]):
            ref = simulate_once(T, CK, PW, 4000.0, ScheduledRNG(gaps[0, k]))
            assert tb.wall_time[0, k] == pytest.approx(ref.wall_time,
                                                       rel=1e-12)
            assert tb.energy[0, k] == pytest.approx(ref.energy, rel=1e-12)
            assert tb.io_time[0, k] == pytest.approx(ref.io_time, rel=1e-12)
            assert tb.work_executed[0, k] == pytest.approx(ref.work_executed,
                                                          rel=1e-12)
            assert int(tb.n_failures[0, k]) == ref.n_failures
            assert int(tb.n_checkpoints[0, k]) == ref.n_checkpoints

    def test_parameter_batch_matches_oracle(self):
        """Different (ckpt, power) points in one batch, same schedules."""
        scens = [get_scenario("fig12", mu_min=120.0),
                 get_scenario("exascale_rho7", mu_min=300.0),
                 get_scenario("fig3", n_nodes=3e5, rho=7.0)]
        grid = grid_from_scenarios(scens)
        T = np.array([40.0, 60.0, 12.0])
        rng = np.random.default_rng(5)
        gaps = rng.exponential(1.0, size=(3, 4, 96)) * grid.mu[:, None, None]
        tb = simulate_trajectories(T, grid, T_base=500.0, gaps=gaps)
        assert not tb.truncated.any()
        for i, sc in enumerate(scens):
            for k in range(gaps.shape[1]):
                ref = simulate_once(float(T[i]), sc.ckpt, sc.power, 500.0,
                                    ScheduledRNG(gaps[i, k]))
                assert tb.wall_time[i, k] == pytest.approx(ref.wall_time,
                                                           rel=1e-12)
                assert tb.energy[i, k] == pytest.approx(ref.energy,
                                                        rel=1e-12)
                assert int(tb.n_failures[i, k]) == ref.n_failures

    def test_no_failure_limit_matches_model(self):
        ck = CheckpointParams(C=10, R=10, D=1, mu=1e12, omega=0.5)
        grid = ParamGrid.from_params(ck, PW).reshape((1,))
        tb = simulate_trajectories(60.0, grid, T_base=1000.0, n_trials=2,
                                   seed=0)
        assert (tb.n_failures == 0).all()
        want = float(model.time_fault_free(60.0, ck, 1000.0))
        assert tb.wall_time == pytest.approx(want, rel=2e-3)


class TestStatisticalParity:
    """Independent seeds -> agreement within 3 standard errors, on at least
    3 registry scenarios (acceptance criterion)."""

    SCENARIOS = [("fig12", dict(mu_min=300.0)),
                 ("exascale_rho7", dict(mu_min=200.0)),
                 ("fig3", dict(n_nodes=5e5, rho=5.5))]

    @pytest.mark.parametrize("name,kw", SCENARIOS)
    def test_means_within_3se(self, name, kw):
        sc = get_scenario(name, **kw)
        T = 1.2 * t_opt_time(sc.ckpt)
        T_base = 2000.0
        grid = ParamGrid.from_params(sc.ckpt, sc.power).reshape((1,))
        out = simulate_grid(T, grid, T_base, n_trials=400, seed=11)
        ref = simulate(T, sc.ckpt, sc.power, T_base, n_trials=400, seed=97)
        for key in ("T_final", "E_final"):
            se = math.hypot(float(out[key + "_se"][0]), ref[key + "_se"])
            assert abs(float(out[key][0]) - ref[key]) < 3.0 * se, (
                f"{name}/{key}: batched {float(out[key][0])} vs scalar "
                f"{ref[key]} (3se={3 * se})")


# ---------------------------------------------------------------------------
# Batched solvers vs scalar solvers
# ---------------------------------------------------------------------------

class TestSolverParity:
    def test_periods_match_scalar_over_grid(self):
        mus = [30.0, 60.0, 120.0, 300.0, 600.0]
        rhos = [1.5, 3.0, 5.5, 7.0, 10.0]
        res = evaluate_grid(mu_rho_grid(mus, rhos))
        for i, mu in enumerate(mus):
            ck = fig12_checkpoint(mu)
            for j, rho in enumerate(rhos):
                pw = PowerParams.from_rho(rho=rho)
                assert res.T_time[i, j] == pytest.approx(t_opt_time(ck),
                                                         rel=1e-9)
                assert res.T_energy[i, j] == pytest.approx(
                    t_opt_energy(ck, pw), rel=1e-7)
                assert res.T_young[i, j] == pytest.approx(t_young(ck),
                                                          rel=1e-12)
                assert res.T_daly[i, j] == pytest.approx(t_daly(ck),
                                                         rel=1e-12)
                assert res.T_msk[i, j] == pytest.approx(
                    t_msk_energy(ck, pw), rel=1e-4)
                pt = evaluate(ck, pw)
                assert res.time_ratio[i, j] == pytest.approx(pt.time_ratio,
                                                             rel=1e-9)
                assert res.energy_ratio[i, j] == pytest.approx(
                    pt.energy_ratio, rel=1e-9)

    def test_degenerate_points_collapse_to_one(self):
        """Fig. 3 right edge: C ~ mu -> periods C, ratios exactly 1."""
        res = evaluate_grid(nodes_grid([1e6, 1e8], EXASCALE_POWER_RHO55))
        assert res.valid[0] and not res.valid[1]
        assert res.time_ratio[1] == 1.0
        assert res.energy_ratio[1] == 1.0
        assert res.T_time[1] == res.grid.C[1]

    def test_tradeoff_sweeps_match_scalar_engine(self):
        from repro.core.tradeoff import sweep_mu_rho
        mus, rhos = [60.0, 300.0], [2.0, 5.5]
        fast = sweep_mu_rho(mus, rhos)
        slow = sweep_mu_rho(mus, rhos, engine="scalar")
        for rf, rs in zip(fast, slow):
            for pf, ps in zip(rf, rs):
                assert pf.energy_ratio == pytest.approx(ps.energy_ratio,
                                                        rel=1e-9)
                assert pf.time_ratio == pytest.approx(ps.time_ratio,
                                                      rel=1e-9)


# ---------------------------------------------------------------------------
# Scenario registry
# ---------------------------------------------------------------------------

class TestScenarios:
    def test_registry_contains_paper_setups(self):
        names = set(list_scenarios())
        assert {"fig12", "fig3", "exascale_rho55", "exascale_rho7"} <= names

    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError):
            get_scenario("nope")

    def test_grid_broadcast_and_views(self):
        grid = mu_rho_grid([60.0, 300.0], [2.0, 5.5, 7.0])
        assert grid.shape == (2, 3)
        assert grid.rho[1, 1] == pytest.approx(5.5)
        ck = grid.ckpt_at((1, 2))
        assert ck.mu == 300.0 and ck.C == 10.0 and ck.omega == 0.5
        pw = grid.power_at((0, 0))
        assert pw.rho == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# t_opt_energy root-selection guard (regression)
# ---------------------------------------------------------------------------

class TestEnergyRootGuard:
    def test_quadratic_root_is_a_minimum_across_stress_grid(self):
        """Invariant: the returned period never loses to the bracket argmin,
        and satisfies the minimum condition Q'(t) > 0."""
        rng = np.random.default_rng(3)
        for _ in range(60):
            ck = CheckpointParams(C=rng.uniform(0.5, 30),
                                  R=rng.uniform(0.1, 30),
                                  D=rng.uniform(0, 5),
                                  mu=rng.uniform(60, 2000),
                                  omega=rng.uniform(0, 1))
            lo0, hi0 = ck.valid_period_range()
            if hi0 <= lo0 * (1 + 1e-6):
                continue
            pw = PowerParams.from_ratios(alpha=10**rng.uniform(-2, 1),
                                         beta=10**rng.uniform(-2, 1.5),
                                         gamma=rng.uniform(0, 2))
            t = t_opt_energy(ck, pw)
            e = float(model.energy_final(t, ck, pw))
            e_num = float(model.energy_final(t_opt_energy_numeric(ck, pw),
                                             ck, pw))
            assert e <= e_num * (1 + 1e-9)
            c2, c1, _ = optimal.energy_quadratic_coefficients(ck, pw)
            lo, hi = optimal._bracket(ck)
            if lo < t < hi and abs(model.K_dE_dT(t, ck, pw)) < 1e-6:
                assert 2.0 * c2 * t + c1 > 0.0

    def test_maximum_root_falls_back_to_numeric(self, monkeypatch):
        """Regression: inject a quadratic whose only in-bracket root is a
        MAXIMUM of the (fake) derivative — the old code returned it blindly;
        the guard must reject it in favour of the numeric argmin."""
        lo, hi = optimal._bracket(CK)
        t_max = 0.5 * (lo + hi)
        # Q(t) = -(t - t_max)^2 + small  has roots just around t_max with
        # Q' < 0 at the larger root and Q' > 0 at the smaller... choose a
        # downward parabola with exactly one in-bracket root, Q' < 0 there:
        t_out = hi + (hi - lo)          # second root far outside the bracket
        c2 = -1.0
        c1 = (t_max + t_out)
        c0 = -t_max * t_out
        # sanity: root t_max is in-bracket and Q'(t_max) = -2 t_max + c1 > 0?
        # Q'(t) = 2*c2*t + c1 = -2t + (t_max + t_out); at t_max this is
        # t_out - t_max > 0 — that's a minimum-branch root.  Flip the sign
        # of all coefficients to make t_max the maximum-branch root.
        c2, c1, c0 = -c2, -c1, -c0
        assert 2.0 * c2 * t_max + c1 <= 0.0
        monkeypatch.setattr(optimal, "energy_quadratic_coefficients",
                            lambda ck, pw: (c2, c1, c0))
        t = optimal.t_opt_energy(CK, PW)
        assert t == pytest.approx(t_opt_energy_numeric(CK, PW), rel=1e-6)


# ---------------------------------------------------------------------------
# Engine misc
# ---------------------------------------------------------------------------

class TestEngineMisc:
    def test_too_short_period_raises(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        with pytest.raises(ValueError):
            simulate_trajectories(4.0, grid, T_base=100.0, n_trials=2)

    def test_scheduled_rng_exhausts_to_inf(self):
        r = ScheduledRNG([5.0])
        assert r.exponential(300.0) == 5.0
        assert math.isinf(r.exponential(300.0))
